"""Fault tolerance table (paper §5.3/§5.4): recovery time + accuracy under
dropout/preemption/partition, sync barrier vs async buffered commits.

The sync loop tolerates faults by partial aggregation (a faulted client's
mask entry is zeroed); the async regime now models them as typed events with
a strike time, and spot-preempted / partitioned clients recover per
``FaultConfig.recovery_policy``:

  discard — the interrupted attempt's work is lost (pre-recovery behaviour),
  restart — retry from scratch against the current global params,
  resume  — partial-progress checkpoint: only the remaining local steps
            re-run (the paper's §5.4 recovery-time story).

Reported per row: commits/updates landed, updates lost to faults, updates
recovered, mean recovery time (extra sim-seconds a recovered update paid vs
its fault-free attempt), and eval accuracy — against a fault-free async
reference so the accuracy cost of the fault regime is explicit.

    PYTHONPATH=src python benchmarks/table_fault_recovery.py
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AsyncConfig, FLConfig
from repro.orchestrator import (AsyncOrchestrator, FaultConfig, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet)
from benchmarks.common import dataset_bundle, save

SIGMA = 0.6
N_POOL = 16
PER_ROUND = 8
BUFFER_K = 4
SYNC_ROUNDS = 6
ASYNC_COMMITS = 12
FLOPS = 2e12
FAULTS = dict(dropout_prob=0.1, spot_preempt_prob=0.3, partition_prob=0.2,
              partition_len=2, recovery_overhead_s=2.0)


def build(seed=0):
    fed, model, params, loss_fn, eval_fn = dataset_bundle(
        "medmnist", n_clients=N_POOL, seed=seed)
    fleet = make_hybrid_fleet(N_POOL // 2, N_POOL - N_POOL // 2, seed=seed,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    return fed, model, params, loss_fn, eval_fn, fleet


def run_sync(faults: FaultConfig, seed=0):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(num_clients=PER_ROUND, local_steps=2, client_lr=0.08),
        straggler=StragglerPolicy(contention_sigma=SIGMA), faults=faults,
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=2, seed=seed)
    t0 = time.time()
    orch.run(params, SYNC_ROUNDS)
    updates = sum(l.participated for l in orch.logs)
    dropped = SYNC_ROUNDS * PER_ROUND - updates
    return {
        "mode": "sync", "policy": "mask", "commits": len(orch.logs),
        "updates_applied": updates, "lost_to_faults": dropped,
        "recovered": 0, "mean_recovery_s": 0.0,
        "sim_time_s": orch.virtual_clock,
        "final_eval": float(orch.logs[-1].eval_metric),
        "wall_s": time.time() - t0,
    }


def run_async(faults: FaultConfig, policy_label: str, seed=0):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(mode="async", num_clients=PER_ROUND, local_steps=2,
                    client_lr=0.08),
        async_cfg=AsyncConfig(buffer_size=BUFFER_K, staleness_exponent=0.5,
                              max_staleness=40, max_concurrency=N_POOL),
        straggler=StragglerPolicy(contention_sigma=SIGMA), faults=faults,
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=4, seed=seed)
    t0 = time.time()
    orch.run(params, num_commits=ASYNC_COMMITS)
    finite = [l.eval_metric for l in orch.logs if np.isfinite(l.eval_metric)]
    mean_rec = (orch.recovery_time_total / orch.recovered_updates
                if orch.recovered_updates else 0.0)
    return {
        "mode": "async", "policy": policy_label, "commits": orch.version,
        "updates_applied": orch.updates_applied,
        "lost_to_faults": orch.lost_to_faults,
        "recovered": orch.recovered_updates, "mean_recovery_s": mean_rec,
        "sim_time_s": orch.clock,
        "final_eval": float(finite[-1]) if finite else float("nan"),
        "wall_s": time.time() - t0,
    }


def main():
    rows = [
        run_async(FaultConfig(), "none (reference)"),
        run_sync(FaultConfig(**FAULTS)),
    ]
    for policy in ("discard", "restart", "resume"):
        rows.append(run_async(FaultConfig(recovery_policy=policy, **FAULTS),
                              policy))
    ref = rows[0]["final_eval"]
    for r in rows:
        r["acc_drop_vs_clean"] = ref - r["final_eval"]
        print(f"table_fault_recovery,mode={r['mode']},policy={r['policy']},"
              f"commits={r['commits']},updates={r['updates_applied']},"
              f"lost={r['lost_to_faults']},recovered={r['recovered']},"
              f"mean_recovery_s={r['mean_recovery_s']:.2f},"
              f"eval={r['final_eval']:.3f},"
              f"acc_drop={r['acc_drop_vs_clean']:.3f}")
    save("table_fault_recovery", {"rows": rows, "faults": FAULTS,
                                  "sigma": SIGMA})
    return rows


if __name__ == "__main__":
    main()
