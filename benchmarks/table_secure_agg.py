"""Secure-aggregation overhead: masked vs plain, sync AND async regimes.

Pairwise additive masking (core.secure_agg) hides every individual client
update from the server — the privacy layer of the paper's §6 — but it is
not free:

  * **bytes** — without quantization masks are dense f32 noise, so the
    uplink reverts to the dense wire size however aggressive the
    compression config is (the historical ~3.9x blowup).  WITH
    quantization the commit masks the quantized wire words in a finite
    ring (integer-domain masking, core.pipeline), so the masked uplink is
    ``quantize_bits + ceil(log2(cohort))`` bits per element
    (``masked_payload_bytes``) — within ~1.25x of the plain quantized
    payload at 8 bits.  Sparsity still does not survive masking (masked
    words are dense), and the downlink keeps its full compression.
  * **wall-clock** — mask generation is K^2 PRF draws per commit inside
    the jit'd step, and the fatter uplink stretches the simulated
    transfer times.
  * **convergence** — ideally NONE: masks cancel within each round/
    commit, so masked and plain aggregation are the same math (the
    <= 1e-5 equality is pinned in tests/test_secure_pipeline.py).  The
    convergence delta reported here isolates what the byte overhead does
    to the simulated timeline (compression on => different event order),
    not any change to the aggregation itself.

    PYTHONPATH=src python benchmarks/table_secure_agg.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset_bundle, save
from repro.core import AsyncConfig, CompressionConfig, FLConfig
from repro.orchestrator import (AsyncOrchestrator, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet)

N_POOL = 12
PER_ROUND = 6
BUFFER_K = 4
SYNC_ROUNDS = 6
ASYNC_COMMITS = 10
FLOPS = 2e12
COMPRESSION = CompressionConfig(quantize_bits=8)   # savings masking destroys


def build(seed=0):
    fed, model, params, loss_fn, eval_fn = dataset_bundle(
        "medmnist", n_clients=N_POOL, seed=seed)
    fleet = make_hybrid_fleet(N_POOL // 2, N_POOL - N_POOL // 2, seed=seed,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    return fed, model, params, loss_fn, eval_fn, fleet


def run_sync(secure: bool, seed=0):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(num_clients=PER_ROUND, local_steps=2, client_lr=0.08,
                    secure_agg=secure, compression=COMPRESSION),
        straggler=StragglerPolicy(contention_sigma=0.5),
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=2, seed=seed)
    t0 = time.time()
    params, _ = orch.run(params, SYNC_ROUNDS)
    return {
        "mode": "sync", "secure_agg": secure,
        "commits": len(orch.logs),
        "bytes_up_total": int(sum(l.bytes_up for l in orch.logs)),
        "sim_time_s": orch.virtual_clock,
        "final_loss": float(orch.logs[-1].client_loss),
        "final_eval": float(orch.logs[-1].eval_metric),
        "wall_s": time.time() - t0,
    }


def run_async(secure: bool, seed=0):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(mode="async", num_clients=PER_ROUND, local_steps=2,
                    client_lr=0.08, secure_agg=secure,
                    compression=COMPRESSION),
        async_cfg=AsyncConfig(buffer_size=BUFFER_K, staleness_exponent=0.5,
                              max_staleness=40, max_concurrency=N_POOL),
        straggler=StragglerPolicy(contention_sigma=0.5),
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=5, seed=seed)
    t0 = time.time()
    params, _ = orch.run(params, num_commits=ASYNC_COMMITS)
    finite = [l.eval_metric for l in orch.logs if np.isfinite(l.eval_metric)]
    return {
        "mode": "async", "secure_agg": secure,
        "commits": orch.version,
        "updates_applied": orch.updates_applied,
        "bytes_up_total": int(sum(l.bytes_up for l in orch.logs)),
        "mask_overhead_bytes": int(sum(l.mask_overhead_bytes
                                       for l in orch.logs)),
        "sim_time_s": orch.clock,
        "mean_staleness": float(np.mean([l.mean_staleness
                                         for l in orch.logs])),
        "final_loss": float(orch.logs[-1].client_loss),
        "final_eval": float(finite[-1]) if finite else float("nan"),
        "wall_s": time.time() - t0,
    }


def main():
    rows = [run_sync(False), run_sync(True),
            run_async(False), run_async(True)]
    table = {}
    for mode in ("sync", "async"):
        plain, sec = [r for r in rows if r["mode"] == mode]
        table[mode] = {
            "bytes_overhead_x": sec["bytes_up_total"]
            / max(plain["bytes_up_total"], 1),
            "sim_time_overhead_x": sec["sim_time_s"]
            / max(plain["sim_time_s"], 1e-9),
            "wall_overhead_x": sec["wall_s"] / max(plain["wall_s"], 1e-9),
            "convergence_delta_loss": sec["final_loss"]
            - plain["final_loss"],
            "convergence_delta_eval": sec["final_eval"]
            - plain["final_eval"],
        }
    for r in rows:
        print(f"table_secure_agg,mode={r['mode']},secure={r['secure_agg']},"
              f"bytes_up={r['bytes_up_total']},sim_s={r['sim_time_s']:.1f},"
              f"loss={r['final_loss']:.4f},eval={r['final_eval']:.4f},"
              f"wall_s={r['wall_s']:.1f}")
    for mode, t in table.items():
        print(f"table_secure_agg,{mode}: bytes x{t['bytes_overhead_x']:.2f}, "
              f"sim-time x{t['sim_time_overhead_x']:.2f}, "
              f"eval delta {t['convergence_delta_eval']:+.4f}")
    save("table_secure_agg", {"rows": rows, "overhead": table,
                              "compression": {"quantize_bits": 8}})
    return rows


if __name__ == "__main__":
    main()
