"""Paper Table 2: FedAvg vs FedProx accuracy on the three datasets under
non-IID partitioning.  Paper numbers (real CIFAR-10/Shakespeare/MedMNIST,
100 rounds): 81.7/83.2, 57.9/59.3, 89.3/90.1 — FedProx > FedAvg everywhere
by 0.8-1.6pp.  The reproduced claim is the ORDERING and the gap direction on
the synthetic stand-ins (absolute values differ with dataset difficulty)."""
from __future__ import annotations

import time

from benchmarks.common import ROUNDS, run_fl, save


def main(rounds: int = None):
    rows = []
    for ds in ("cifar10", "shakespeare", "medmnist"):
        t0 = time.time()
        res_avg = run_fl(ds, "fedavg", rounds=rounds)
        res_prox = run_fl(ds, "fedprox", rounds=rounds)
        rows.append({
            "dataset": ds,
            "fedavg_acc": res_avg["final_acc"],
            "fedprox_acc": res_prox["final_acc"],
            "fedavg_trace": res_avg["acc_trace"],
            "fedprox_trace": res_prox["acc_trace"],
            "wall_s": time.time() - t0,
        })
        print(f"table2,{ds},fedavg={res_avg['final_acc']:.4f},"
              f"fedprox={res_prox['final_acc']:.4f}")
    save("table2_accuracy", {"rounds": rounds or ROUNDS, "rows": rows,
                             "paper": {"cifar10": (81.7, 83.2),
                                       "shakespeare": (57.9, 59.3),
                                       "medmnist": (89.3, 90.1)}})
    return rows


if __name__ == "__main__":
    main()
