"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig, FLConfig
from repro.data import (FederatedDataset, cifar10_like, medmnist_like,
                        partition_by_class, partition_by_group,
                        shakespeare_like)
from repro.models import build_model
from repro.models.cnn import CNN, CNNConfig
from repro.configs import get_config
from repro.orchestrator import (FaultConfig, Orchestrator, StragglerPolicy,
                                make_hybrid_fleet)

ART = Path(os.environ.get("REPRO_BENCH_DIR", "artifacts/bench"))
# paper protocol: 100 rounds; CPU-budgeted default below, override with env
ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "24"))

CNN_SMALL = CNNConfig("bench-cifar-cnn", (32, 32, 3), 10, channels=(16, 32),
                      dense=128)
MED_SMALL = CNNConfig("bench-med-cnn", (28, 28, 1), 9, channels=(16, 32),
                      dense=128)


def save(name: str, payload: dict):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1))


def dataset_bundle(which: str, n_clients: int = 20, seed: int = 0):
    """(fed_dataset, model_obj, params, loss_fn, eval_fn)."""
    if which == "cifar10":
        ds = cifar10_like(n=6000, seed=seed, noise=1.25)
        parts = partition_by_class(ds.y, n_clients, 2, seed=seed)
        model = CNN(CNN_SMALL)
    elif which == "medmnist":
        ds = medmnist_like(n=5000, seed=seed + 1)
        parts = partition_by_class(ds.y, n_clients, 3, seed=seed)
        model = CNN(MED_SMALL)
    elif which == "shakespeare":
        ds = shakespeare_like(n_seqs=3000, seq_len=48, n_speakers=n_clients * 2,
                              seed=seed + 2)
        parts = partition_by_group(ds.y, n_clients, seed=seed)
        cfg = get_config("paper-charlm")
        model = build_model(cfg)
    else:
        raise ValueError(which)
    fed = FederatedDataset(ds, parts, seed=seed)
    params = model.init(jax.random.PRNGKey(seed))
    if which == "shakespeare":
        eval_batch = jax.tree.map(jnp.asarray, fed.eval_batch(384))

        @jax.jit
        def eval_fn(p):
            # next-char accuracy (the LEAF Shakespeare metric)
            toks = eval_batch["tokens"]
            x = model.embed(p, toks)
            import repro.models.sharding  # noqa
            pos = jnp.arange(toks.shape[1])
            h, _, _ = model._backbone(p, x, mode="train", positions=pos)
            from repro.models.common import rms_norm
            h = rms_norm(h, p["final_norm"], model.cfg.norm_eps)
            lg = model.logits(p, h)[..., :model.cfg.vocab]
            return (lg.argmax(-1) == eval_batch["targets"]).mean()
    else:
        eval_batch = jax.tree.map(jnp.asarray, fed.eval_batch(768))
        acc = jax.jit(model.accuracy)
        eval_fn = lambda p: acc(p, eval_batch)
    return fed, model, params, model.loss_fn, eval_fn


def run_fl(which: str, algo: str = "fedavg", rounds: int = None,
           n_clients_pool: int = 20, clients_per_round: int = 8,
           compression: CompressionConfig = None,
           straggler: StragglerPolicy = None, faults: FaultConfig = None,
           selection: str = "adaptive", seed: int = 0,
           flops_per_client_round: float = 2e12, batch_size: int = 16,
           local_steps: int = 5, lr: float = None):
    fed, model, params, loss_fn, eval_fn = dataset_bundle(
        which, n_clients_pool, seed)
    fl = FLConfig(
        num_clients=clients_per_round, local_steps=local_steps,
        client_lr=lr or (0.3 if which == "shakespeare" else 0.08),
        fedprox_mu=0.05 if algo == "fedprox" else 0.0,
        compression=compression or CompressionConfig())
    fleet = make_hybrid_fleet(n_clients_pool // 2,
                              n_clients_pool - n_clients_pool // 2,
                              seed=seed,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn, fl=fl,
        selection_name=selection,
        straggler=straggler or StragglerPolicy(),
        faults=faults or FaultConfig(),
        batch_size=batch_size, flops_per_client_round=flops_per_client_round,
        eval_fn=eval_fn, eval_every=max((rounds or ROUNDS) // 4, 1), seed=seed)
    t0 = time.time()
    params, _ = orch.run(params, rounds or ROUNDS)
    return {
        "final_acc": float(orch.logs[-1].eval_metric),
        "acc_trace": [l.eval_metric for l in orch.logs
                      if np.isfinite(l.eval_metric)],
        "loss_trace": [l.client_loss for l in orch.logs],
        "virtual_time_s": orch.virtual_clock,
        "mean_round_s": float(np.mean([l.duration_s for l in orch.logs])),
        "bytes_per_client_round": orch.comm.mean_bytes_per_client_round(),
        "wall_s": time.time() - t0,
        "orch": orch,
    }
