"""Sync-barrier vs buffered-async on a straggler-heavy hybrid fleet.

The sync round loop commits once per round and the round lasts as long as
its slowest participant — on a heterogeneous HPC+cloud fleet with lognormal
contention noise (sigma >= 0.5) the barrier is dominated by the tail.  The
FedBuff-style async orchestrator keeps every node busy and commits every K
arrivals, so fast nodes lap slow ones instead of waiting.

Reported per mode:
  * updates/sim-s   — client update-commits applied per simulated second
                      (the throughput lever the barrier throttles),
  * commits/sim-s   — server aggregate commits per simulated second,
  * loss @ equal simulated time — convergence is not sacrificed,
  * mean staleness / dropped updates — the price async pays.

    PYTHONPATH=src python benchmarks/table_async.py
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import AsyncConfig, FLConfig
from repro.data import (VirtualFederatedDataset, medmnist_like,
                        partition_dirichlet)
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (AsyncOrchestrator, BatchedAsyncOrchestrator,
                                EventWindowOrchestrator, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet,
                                make_mega_fleet)
from benchmarks.common import dataset_bundle, save

SIGMA = 0.6                 # lognormal contention noise (>= 0.5 per protocol)
N_POOL = 16                 # hybrid fleet size (half HPC, half cloud)
PER_ROUND = 8               # sync: clients per barrier round
BUFFER_K = 4                # async: commit every K arrivals
SYNC_ROUNDS = 6
FLOPS = 2e12


def build(seed=0):
    fed, model, params, loss_fn, eval_fn = dataset_bundle(
        "medmnist", n_clients=N_POOL, seed=seed)
    fleet = make_hybrid_fleet(N_POOL // 2, N_POOL - N_POOL // 2, seed=seed,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    return fed, model, params, loss_fn, eval_fn, fleet


def run_sync(seed=0, n_rounds=SYNC_ROUNDS):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(num_clients=PER_ROUND, local_steps=2, client_lr=0.08),
        straggler=StragglerPolicy(contention_sigma=SIGMA),
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=2, seed=seed)
    t0 = time.time()
    params, _ = orch.run(params, n_rounds)
    updates = sum(l.participated for l in orch.logs)
    return {
        "mode": "sync", "commits": len(orch.logs),
        "updates_applied": updates,
        "sim_time_s": orch.virtual_clock,
        "updates_per_sim_s": updates / orch.virtual_clock,
        "commits_per_sim_s": len(orch.logs) / orch.virtual_clock,
        "final_loss": float(orch.logs[-1].client_loss),
        "final_eval": float(orch.logs[-1].eval_metric),
        "wall_s": time.time() - t0,
    }


def run_async(sim_budget_s: float, seed=0):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(mode="async", num_clients=PER_ROUND, local_steps=2,
                    client_lr=0.08),
        async_cfg=AsyncConfig(buffer_size=BUFFER_K, staleness_exponent=0.5,
                              max_staleness=40, commit_timeout_s=0.0,
                              max_concurrency=N_POOL),
        straggler=StragglerPolicy(contention_sigma=SIGMA),
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=5, seed=seed)
    t0 = time.time()
    # same SIMULATED time budget the sync barrier spent
    params, _ = orch.run(params, num_commits=10_000,
                         max_sim_time=sim_budget_s)
    finite = [l.eval_metric for l in orch.logs if np.isfinite(l.eval_metric)]
    return {
        "mode": "async", "commits": orch.version,
        "updates_applied": orch.updates_applied,
        "dropped_stale": orch.dropped_stale,
        "sim_time_s": orch.clock,
        "updates_per_sim_s": orch.updates_per_sim_second,
        "commits_per_sim_s": orch.commits_per_sim_second,
        "mean_staleness": float(np.mean([l.mean_staleness
                                         for l in orch.logs])),
        "final_loss": float(orch.logs[-1].client_loss),
        "final_eval": float(finite[-1]) if finite else float("nan"),
        "wall_s": time.time() - t0,
    }


def main(rounds: int = None):
    sync = run_sync(n_rounds=rounds or SYNC_ROUNDS)
    anc = run_async(sim_budget_s=sync["sim_time_s"])
    speedup = anc["updates_per_sim_s"] / sync["updates_per_sim_s"]
    rows = [sync, anc]
    for r in rows:
        print(f"table_async,mode={r['mode']},commits={r['commits']},"
              f"updates={r['updates_applied']},sim_s={r['sim_time_s']:.1f},"
              f"updates_per_sim_s={r['updates_per_sim_s']:.4f},"
              f"loss={r['final_loss']:.4f}")
    print(f"table_async,update_throughput_speedup={speedup:.2f}x "
          f"(acceptance: >= 1.5x)")
    save("table_async", {"rows": rows, "sigma": SIGMA,
                         "speedup_updates_per_sim_s": speedup})
    return rows


# ---------------------------------------------------------------- mega sweep
# Fleet-size sweep 1e2 -> 1e6: the per-event engine vs the batched engine vs
# the vectorized event-window engine on the SAME CohortFleet + virtual
# dataset + MLP workload.  Headline is wall-clock per simulated second — the
# engine-overhead metric that decides whether a mega-client population is
# simulable at all.  Legacy stops at 1k (its O(population) selection scan
# makes 10k+ runs pointless to wait for); batched stops at 100k (the
# per-event heap churn + per-bucket host syncs the window engine removes);
# only the window engine runs the 1e6 row.  Each row also carries the
# CommitLog phase breakdown (dispatch/train/commit/host_sync wall seconds +
# host-sync count, summed over the run) so engine regressions are
# attributable to a phase.

SWEEP_SIZES = [100, 1_000, 10_000, 100_000, 1_000_000]
LEGACY_MAX = 1_000
BATCHED_MAX = 100_000
SWEEP_COMMITS = 30
SWEEP_BUFFER_K = 16
SWEEP_CFG = CNNConfig("sweep-mlp", (28, 28, 1), 9, channels=(), dense=64)
PHASES = ("dispatch", "train", "commit", "host_sync")


def run_fleet(n_clients: int, engine: str, seed: int = 0):
    data = medmnist_like(n=600, seed=seed)
    parts = partition_dirichlet(data.y, 8, alpha=0.5, seed=seed)
    model = CNN(SWEEP_CFG)
    params = model.init(jax.random.PRNGKey(seed))
    cls = {"legacy": AsyncOrchestrator,
           "batched": BatchedAsyncOrchestrator,
           "window": EventWindowOrchestrator}[engine]
    orch = cls(
        fleet=make_mega_fleet(n_clients, seed=3),
        fed_data=VirtualFederatedDataset(data, parts, seed=seed,
                                         n_virtual=n_clients),
        loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=n_clients, local_steps=2,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=SWEEP_BUFFER_K,
                              max_concurrency=min(n_clients, 128),
                              max_staleness=100),
        straggler=StragglerPolicy(contention_sigma=0.5),
        batch_size=8, flops_per_client_round=1e12, seed=7)
    t0 = time.perf_counter()
    orch.run(params, SWEEP_COMMITS)
    wall = time.perf_counter() - t0
    updates = orch.updates_applied
    row = {
        "n_clients": n_clients, "engine": engine,
        "commits": orch.version, "updates_applied": updates,
        "sim_time_s": orch.clock, "wall_s": wall,
        "wall_per_sim_s": wall / orch.clock,
        "ms_per_update": 1e3 * wall / max(updates, 1),
    }
    for k in PHASES:
        row[f"wall_{k}_s"] = round(
            sum(l.phase_wall.get(k, 0.0) for l in orch.logs), 3)
    row["host_syncs"] = sum(l.phase_wall.get("host_syncs", 0)
                            for l in orch.logs)
    return row


def sweep():
    rows = []
    for n in SWEEP_SIZES:
        engines = ["window"]
        if n <= BATCHED_MAX:
            engines.insert(0, "batched")
        if n <= LEGACY_MAX:
            engines.insert(0, "legacy")
        for engine in engines:
            r = run_fleet(n, engine)
            rows.append(r)
            print(f"table_megafleet,n={r['n_clients']},engine={r['engine']},"
                  f"commits={r['commits']},updates={r['updates_applied']},"
                  f"sim_s={r['sim_time_s']:.1f},wall_s={r['wall_s']:.2f},"
                  f"wall_per_sim_s={r['wall_per_sim_s']:.4f},"
                  f"ms_per_update={r['ms_per_update']:.2f},"
                  f"host_syncs={r['host_syncs']},"
                  + ",".join(f"wall_{k}_s={r[f'wall_{k}_s']}"
                             for k in PHASES))
    by = {(r["n_clients"], r["engine"]): r for r in rows}
    speedup_1k = (by[(1_000, "legacy")]["wall_per_sim_s"]
                  / by[(1_000, "batched")]["wall_per_sim_s"])
    ratio_1m = (by[(1_000_000, "window")]["wall_per_sim_s"]
                / by[(100_000, "window")]["wall_per_sim_s"])
    print(f"table_megafleet,wall_per_sim_s_speedup_1k={speedup_1k:.1f}x,"
          f"1e6_vs_1e5_wall_per_sim_s={ratio_1m:.2f}x "
          f"(acceptance: 1e6 row within 2x of the 100k row)")
    save("table_megafleet", {
        "rows": rows, "buffer_k": SWEEP_BUFFER_K, "commits": SWEEP_COMMITS,
        "wall_per_sim_s_speedup_1k": speedup_1k,
        "wall_per_sim_s_1e6_over_1e5": ratio_1m,
        "engine_auto_crossover_clients": 300,
        "largest_completed_fleet": max(r["n_clients"] for r in rows),
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="run the 1e2->1e5 fleet-size engine sweep instead "
                         "of the sync-vs-async table")
    args = ap.parse_args()
    if args.sweep:
        sweep()
    else:
        main()
