"""Sync-barrier vs buffered-async on a straggler-heavy hybrid fleet.

The sync round loop commits once per round and the round lasts as long as
its slowest participant — on a heterogeneous HPC+cloud fleet with lognormal
contention noise (sigma >= 0.5) the barrier is dominated by the tail.  The
FedBuff-style async orchestrator keeps every node busy and commits every K
arrivals, so fast nodes lap slow ones instead of waiting.

Reported per mode:
  * updates/sim-s   — client update-commits applied per simulated second
                      (the throughput lever the barrier throttles),
  * commits/sim-s   — server aggregate commits per simulated second,
  * loss @ equal simulated time — convergence is not sacrificed,
  * mean staleness / dropped updates — the price async pays.

    PYTHONPATH=src python benchmarks/table_async.py
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AsyncConfig, FLConfig
from repro.orchestrator import (AsyncOrchestrator, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet)
from benchmarks.common import dataset_bundle, save

SIGMA = 0.6                 # lognormal contention noise (>= 0.5 per protocol)
N_POOL = 16                 # hybrid fleet size (half HPC, half cloud)
PER_ROUND = 8               # sync: clients per barrier round
BUFFER_K = 4                # async: commit every K arrivals
SYNC_ROUNDS = 6
FLOPS = 2e12


def build(seed=0):
    fed, model, params, loss_fn, eval_fn = dataset_bundle(
        "medmnist", n_clients=N_POOL, seed=seed)
    fleet = make_hybrid_fleet(N_POOL // 2, N_POOL - N_POOL // 2, seed=seed,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    return fed, model, params, loss_fn, eval_fn, fleet


def run_sync(seed=0, n_rounds=SYNC_ROUNDS):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(num_clients=PER_ROUND, local_steps=2, client_lr=0.08),
        straggler=StragglerPolicy(contention_sigma=SIGMA),
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=2, seed=seed)
    t0 = time.time()
    params, _ = orch.run(params, n_rounds)
    updates = sum(l.participated for l in orch.logs)
    return {
        "mode": "sync", "commits": len(orch.logs),
        "updates_applied": updates,
        "sim_time_s": orch.virtual_clock,
        "updates_per_sim_s": updates / orch.virtual_clock,
        "commits_per_sim_s": len(orch.logs) / orch.virtual_clock,
        "final_loss": float(orch.logs[-1].client_loss),
        "final_eval": float(orch.logs[-1].eval_metric),
        "wall_s": time.time() - t0,
    }


def run_async(sim_budget_s: float, seed=0):
    fed, model, params, loss_fn, eval_fn, fleet = build(seed)
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(mode="async", num_clients=PER_ROUND, local_steps=2,
                    client_lr=0.08),
        async_cfg=AsyncConfig(buffer_size=BUFFER_K, staleness_exponent=0.5,
                              max_staleness=40, commit_timeout_s=0.0,
                              max_concurrency=N_POOL),
        straggler=StragglerPolicy(contention_sigma=SIGMA),
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=5, seed=seed)
    t0 = time.time()
    # same SIMULATED time budget the sync barrier spent
    params, _ = orch.run(params, num_commits=10_000,
                         max_sim_time=sim_budget_s)
    finite = [l.eval_metric for l in orch.logs if np.isfinite(l.eval_metric)]
    return {
        "mode": "async", "commits": orch.version,
        "updates_applied": orch.updates_applied,
        "dropped_stale": orch.dropped_stale,
        "sim_time_s": orch.clock,
        "updates_per_sim_s": orch.updates_per_sim_second,
        "commits_per_sim_s": orch.commits_per_sim_second,
        "mean_staleness": float(np.mean([l.mean_staleness
                                         for l in orch.logs])),
        "final_loss": float(orch.logs[-1].client_loss),
        "final_eval": float(finite[-1]) if finite else float("nan"),
        "wall_s": time.time() - t0,
    }


def main(rounds: int = None):
    sync = run_sync(n_rounds=rounds or SYNC_ROUNDS)
    anc = run_async(sim_budget_s=sync["sim_time_s"])
    speedup = anc["updates_per_sim_s"] / sync["updates_per_sim_s"]
    rows = [sync, anc]
    for r in rows:
        print(f"table_async,mode={r['mode']},commits={r['commits']},"
              f"updates={r['updates_applied']},sim_s={r['sim_time_s']:.1f},"
              f"updates_per_sim_s={r['updates_per_sim_s']:.4f},"
              f"loss={r['final_loss']:.4f}")
    print(f"table_async,update_throughput_speedup={speedup:.2f}x "
          f"(acceptance: >= 1.5x)")
    save("table_async", {"rows": rows, "sigma": SIGMA,
                         "speedup_updates_per_sim_s": speedup})
    return rows


if __name__ == "__main__":
    main()
