"""Paper Table 3: scalability 10 -> 60 clients; total time to process a fixed
workload drops near-linearly (paper: 100 min -> 22 min, 4.55x at 6x clients).

Reproduction: fixed total sample budget; per round, `clients` nodes each run
`local_steps x batch` samples in parallel, so rounds_needed ~ 1/clients.
Round duration = slowest participating node (heterogeneous profiles with
contention noise) — giving sub-linear speedup exactly as the paper observes.
The jit'd round step provides the real per-round compute; node wall-times
come from the calibrated profiles (virtual clock)."""
from __future__ import annotations

import numpy as np

from repro.core import FLConfig
from repro.data import FederatedDataset, cifar10_like, partition_by_class
from repro.models.cnn import CNN
from repro.orchestrator import (Orchestrator, StragglerPolicy,
                                make_hybrid_fleet)
from benchmarks.common import CNN_SMALL, save
import jax


TOTAL_SAMPLES = 60_000          # fixed training workload
BATCH, LOCAL_STEPS = 16, 4
SAMPLES_PER_CLIENT_ROUND = BATCH * LOCAL_STEPS


def run_scale(n_clients: int, seed: int = 0, real_rounds: int = 2):
    ds = cifar10_like(n=4000, seed=seed)
    parts = partition_by_class(ds.y, n_clients, 2, seed=seed)
    fed = FederatedDataset(ds, parts)
    model = CNN(CNN_SMALL)
    params = model.init(jax.random.PRNGKey(seed))
    fleet = make_hybrid_fleet(n_clients // 2, n_clients - n_clients // 2,
                              seed=seed,
                              data_sizes=[len(p) for p in parts])
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=FLConfig(num_clients=n_clients, local_steps=LOCAL_STEPS,
                    client_lr=0.05),
        straggler=StragglerPolicy(contention_sigma=0.25),
        batch_size=BATCH, flops_per_client_round=3e12, seed=seed)
    # run a few real rounds (jit'd math), extrapolate the virtual clock over
    # the full workload
    params, _ = orch.run(params, real_rounds)
    mean_round = float(np.mean([l.duration_s for l in orch.logs]))
    rounds_needed = TOTAL_SAMPLES / (n_clients * SAMPLES_PER_CLIENT_ROUND)
    return mean_round * rounds_needed / 60.0      # minutes


def main(rounds: int = None):
    base = None
    rows = []
    for n in (10, 20, 30, 40, 50, 60):
        minutes = run_scale(n)
        base = base or minutes
        rows.append({"clients": n, "total_min": round(minutes, 1),
                     "speedup": round(base / minutes, 2)})
        print(f"table3,clients={n},total_min={minutes:.1f},"
              f"speedup={base/minutes:.2f}")
    save("table3_scalability", {
        "rows": rows,
        "paper": [(10, 100, 1.0), (20, 58, 1.72), (30, 43, 2.32),
                  (40, 33, 3.03), (50, 27, 3.70), (60, 22, 4.55)]})
    return rows


if __name__ == "__main__":
    main()
