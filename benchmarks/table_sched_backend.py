"""Closed-form vs scheduler-backed execution on the paper's 30+30 fleet.

Both backends draw the same base work per client (compute + transfer +
lognormal contention), so every difference in the table is SCHEDULING:
queue wait behind a 12-node SLURM partition shared by 30 HPC clients,
elastic HPC->cloud overflow when the partition saturates, K8s autoscaling,
and spot preemptions from the adapter's reclaim stream.  This is the
dynamics the paper's §3.2 resource-scheduling story is about — the
closed-form model prices the link and the node, the scheduler backend
additionally prices WAITING for them.

Reported per backend:
  * round-time distribution (mean/p50/p90) over the barrier rounds,
  * mean queue wait + overflow/preemption counts (zero for closed form),
  * accuracy vs simulated wall-clock (same model quality, later clock).

    PYTHONPATH=src python benchmarks/table_sched_backend.py
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import FLConfig
from repro.exec import make_backend
from repro.orchestrator import (Orchestrator, StragglerPolicy,
                                make_hybrid_fleet)
from repro.sched import K8sAdapter, SlurmAdapter
from benchmarks.common import ROUNDS, dataset_bundle, save

N_HPC = N_CLOUD = 30        # the paper's §5.1 testbed
PER_ROUND = 20
SLURM_NODES = 6             # a 20-client round contends for 6+6 nodes:
K8S_MAX = 6                 # queue waits + overflow are unavoidable
PREEMPT_PER_MIN = 6.0       # ~10 s mean spot lifetime vs ~5 s rounds
SIGMA = 0.5
FLOPS = 2e12


def build_backend(kind: str, seed: int):
    if kind == "closed-form":
        return make_backend("closed-form")
    return make_backend(
        "scheduler",
        slurm=SlurmAdapter(total_nodes=SLURM_NODES, seed=seed),
        k8s=K8sAdapter(initial_nodes=K8S_MAX // 2, max_nodes=K8S_MAX,
                       preempt_prob_per_min=PREEMPT_PER_MIN, seed=seed + 1))


def run(kind: str, n_rounds: int, seed: int = 0) -> dict:
    fed, model, params, loss_fn, eval_fn = dataset_bundle(
        "medmnist", n_clients=N_HPC + N_CLOUD, seed=seed)
    fleet = make_hybrid_fleet(N_HPC, N_CLOUD, seed=seed,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=loss_fn,
        fl=FLConfig(num_clients=PER_ROUND, local_steps=2, client_lr=0.08),
        straggler=StragglerPolicy(contention_sigma=SIGMA),
        batch_size=16, flops_per_client_round=FLOPS,
        eval_fn=eval_fn, eval_every=4, backend=build_backend(kind, seed),
        seed=seed)
    t0 = time.time()
    orch.run(params, n_rounds)
    durs = np.asarray([l.duration_s for l in orch.logs])
    curve = [(float(np.sum(durs[:i + 1])), float(l.eval_metric))
             for i, l in enumerate(orch.logs) if np.isfinite(l.eval_metric)]
    return {
        "backend": kind, "rounds": n_rounds,
        "round_time_mean_s": float(durs.mean()),
        "round_time_p50_s": float(np.percentile(durs, 50)),
        "round_time_p90_s": float(np.percentile(durs, 90)),
        "sim_time_s": float(durs.sum()),
        "mean_queue_wait_s": float(np.mean([l.mean_queue_wait_s
                                            for l in orch.logs])),
        "overflow_clients": int(sum(l.n_overflow for l in orch.logs)),
        "overflow_rate": float(sum(l.n_overflow for l in orch.logs)
                               / (n_rounds * PER_ROUND)),
        "preempted_clients": int(sum(l.n_preempted for l in orch.logs)),
        "final_eval": float(orch.logs[-1].eval_metric),
        "accuracy_vs_sim_time": curve,
        "wall_s": time.time() - t0,
    }


def main(rounds: int | None = None):
    n = rounds or ROUNDS
    rows = [run("closed-form", n), run("scheduler", n)]
    for r in rows:
        print(f"table_sched_backend,backend={r['backend']},"
              f"round_mean={r['round_time_mean_s']:.2f}s,"
              f"p90={r['round_time_p90_s']:.2f}s,"
              f"queue_wait={r['mean_queue_wait_s']:.2f}s,"
              f"overflow_rate={r['overflow_rate']:.3f},"
              f"preempted={r['preempted_clients']},"
              f"eval={r['final_eval']:.4f}")
    cf, sc = rows
    slowdown = sc["sim_time_s"] / cf["sim_time_s"]
    print(f"table_sched_backend,sched_vs_closed_sim_time={slowdown:.2f}x "
          f"(queue wait lengthens rounds; early preempt strikes release "
          f"the barrier — dynamics the closed form cannot see)")
    save("table_sched_backend", {
        "rows": rows,
        "fleet": {"n_hpc": N_HPC, "n_cloud": N_CLOUD,
                  "slurm_nodes": SLURM_NODES, "k8s_max_nodes": K8S_MAX,
                  "preempt_per_min": PREEMPT_PER_MIN, "sigma": SIGMA},
        "sim_time_slowdown": slowdown,
    })
    return rows


if __name__ == "__main__":
    main()
