"""Roofline analysis (deliverable g): reads launch/dryrun.py artifacts and
emits the per-(arch x shape x mesh) three-term roofline table.

  compute term    = FLOPs / (chips x 197 TFLOP/s)        [analytic, costmodel]
  memory term     = HBM bytes / (chips x 819 GB/s)       [analytic, costmodel]
  collective term = collective bytes / (50 GB/s/link)    [measured from the
                    partitioned HLO with while-trip-count multipliers;
                    bytes are per-device participation volumes]

Train rows also carry the server-commit HBM bytes fused vs unfused
(costmodel.commit_bytes_touched) — the fused Pallas commit path's
predicted bytes-touched ratio, validated empirically by
benchmarks/table_kernel_fusion.py.

Run:  PYTHONPATH=src python -m benchmarks.roofline [--artifacts artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks import costmodel as cm


def load_artifacts(art_dir: Path) -> list[dict]:
    out = []
    for p in sorted(art_dir.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("groups_override"):
            continue                       # decomposition runs, not baselines
        out.append(d)
    return out


def analyse(d: dict) -> dict:
    n_chips = d.get("n_devices", 256)
    if "skipped" in d or "error" in d:
        return {**d, "status": "skipped" if "skipped" in d else "ERROR"}
    cb = d.get("collective_bytes", {})
    coll = sum(v for k, v in cb.items() if not k.endswith("/cross_pod"))
    cross = sum(v for k, v in cb.items() if k.endswith("/cross_pod"))
    terms = cm.roofline_terms(
        d["arch"], d["shape"], n_chips, coll,
        clients=d.get("clients", 0), local_steps=d.get("local_steps", 1))
    terms["crosspod_s"] = cross / cm.DCN_BW
    if terms["crosspod_s"] > terms[terms["dominant"] + "_s"]:
        terms["dominant"] = "crosspod"
    mem = d.get("memory_analysis", {})
    return {
        **d, "status": "ok", **terms,
        "collective_bytes_total": coll,
        "temp_bytes_per_device": mem.get("temp_size_in_bytes", 0) / n_chips
        if isinstance(mem.get("temp_size_in_bytes"), (int, float)) else None,
    }


def one_liner(r: dict) -> str:
    if r["status"] != "ok":
        reason = r.get("skipped", r.get("error", ""))[:60]
        return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
                f"-- {r['status']}: {reason}")
    commit = (f"  commit-fused {r['commit_fused_x']:.3f}x"
              if "commit_fused_x" in r else "")
    return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
            f"compute {r['compute_s']:9.4f}s  mem {r['memory_s']:9.4f}s  "
            f"coll {r['collective_s']:9.4f}s  -> {r['dominant']:10s} "
            f"useful {r['useful_ratio']:5.2f}{commit}")


def what_would_help(r: dict) -> str:
    dom = r.get("dominant")
    if dom == "compute":
        return ("compute-bound: raise MFU — larger per-chip tiles, fewer "
                "remat recomputes, or fewer clients x local steps per round")
    if dom == "memory":
        return ("HBM-bound: cut activation traffic (longer fused chains, "
                "flash-style attention) and weight re-reads (cache gathered "
                "experts across the top-k loop)")
    return ("collective-bound: shrink per-layer weight gathers (keep experts "
            "resident per model shard), compress/quantize the delta "
            "all-reduce, overlap collectives with compute")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    arts = load_artifacts(Path(args.artifacts))
    results = [analyse(d) for d in arts]
    print(f"{'arch':24s} {'shape':12s} {'mesh':6s} roofline terms (s/step)")
    print("-" * 118)
    for r in results:
        print(one_liner(r))
    Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.json_out).write_text(json.dumps(results, indent=1, default=str))
    ok = [r for r in results if r["status"] == "ok"]
    if ok:
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\n{len(ok)} analysed; dominant terms: {doms}")
    return results


if __name__ == "__main__":
    main()
