"""Hierarchical cross-facility federation vs the flat topology (paper §3.2).

Matched protocol: every row trains the SAME total number of tier-1 rounds
over the SAME fleet — the flat baseline runs them against one server, the
hierarchical rows split the fleet into facilities that each run
``LOCAL_ROUNDS`` rounds per tier-2 commit and ship ONE delta per commit
over the modeled WAN (``comm.WANTopology``, dcn link class).  What the
table shows:

  * accuracy parity — two-tier aggregation matches flat quality at the
    same tier-1 round budget;
  * WAN traffic — the hierarchy moves `2 x facilities x commits` payloads
    across the WAN instead of `2 x clients x rounds` (the paper's motivation
    for facility-local aggregation);
  * wall clock vs WAN bandwidth — the sweep prices the same run on
    progressively worse inter-facility links; only the WAN legs stretch,
    facility-local time is untouched.

    PYTHONPATH=src:. python benchmarks/table_hierarchy.py
"""
from __future__ import annotations

import time

from repro.comm.transport import WANTopology
from repro.core import FLConfig
from repro.orchestrator import (HierarchicalOrchestrator, Orchestrator,
                                make_facilities, make_hybrid_fleet)
from benchmarks.common import ROUNDS, dataset_bundle, save

N_CLIENTS = 24              # 12 HPC + 12 cloud, split across facilities
PER_ROUND = 8               # clients per tier-1 round (per facility server)
LOCAL_ROUNDS = 2            # tier-1 rounds per tier-2 commit
SEED = 0
FLOPS = 2e12


def _fleet_and_data():
    fed, model, params, loss_fn, eval_fn = dataset_bundle(
        "medmnist", n_clients=N_CLIENTS, seed=SEED)
    fleet = make_hybrid_fleet(N_CLIENTS // 2, N_CLIENTS // 2, seed=SEED,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    return fed, model, params, loss_fn, eval_fn, fleet


def run_flat(n_rounds: int) -> dict:
    fed, model, params, loss_fn, eval_fn, fleet = _fleet_and_data()
    fl = FLConfig(num_clients=PER_ROUND, local_steps=2, client_lr=0.08)
    orch = Orchestrator(fleet=fleet, fed_data=fed, loss_fn=loss_fn, fl=fl,
                        batch_size=16, flops_per_client_round=FLOPS,
                        eval_fn=eval_fn, eval_every=4, seed=SEED)
    t0 = time.time()
    p, _ = orch.run(params, n_rounds)
    evals = [l.eval_metric for l in orch.logs if l.eval_metric == l.eval_metric]
    return {
        "topology": "flat", "facilities": 1,
        "wan_GBps": None, "tier1_rounds": n_rounds,
        "accuracy": float(eval_fn(p)),
        "final_eval": float(evals[-1]) if evals else float("nan"),
        # in the flat topology EVERY client payload crosses the server
        # uplink — that is the traffic the hierarchy pulls off the WAN
        "wan_bytes": orch.comm.total_bytes(),
        "total_bytes": orch.comm.total_bytes(),
        "sim_time_s": float(orch.virtual_clock),
        "bench_wall_s": time.time() - t0,
    }


def run_hier(n_fac: int, commits: int, wan_GBps: float | None = None) -> dict:
    fed, model, params, loss_fn, eval_fn, fleet = _fleet_and_data()
    fl = FLConfig(num_clients=PER_ROUND, local_steps=2, client_lr=0.08)
    facs = make_facilities(
        n_fac, fleet, fed, loss_fn, fl, local_mode="sync",
        local_rounds=LOCAL_ROUNDS, seed=SEED,
        orch_kw=dict(batch_size=16, flops_per_client_round=FLOPS))
    wan = WANTopology()
    if wan_GBps is not None:
        for i in range(n_fac):
            wan.set_pair("server", f"fac{i}", bandwidth_GBps=wan_GBps)
    hier = HierarchicalOrchestrator(facs, fl, inter_mode="sync", wan=wan,
                                    eval_fn=eval_fn, eval_every=2, seed=SEED)
    t0 = time.time()
    p, _ = hier.run(params, commits)
    return {
        "topology": "hierarchical", "facilities": n_fac,
        "wan_GBps": wan_GBps, "tier1_rounds": commits * LOCAL_ROUNDS,
        "accuracy": float(eval_fn(p)),
        "final_eval": float(hier.logs[-1].eval_metric),
        "wan_bytes": hier.inter_facility_bytes,
        "total_bytes": hier.total_bytes(),
        "sim_time_s": float(hier.clock),
        "bench_wall_s": time.time() - t0,
    }


def main():
    commits = max(ROUNDS // LOCAL_ROUNDS, 2)
    rows = [run_flat(commits * LOCAL_ROUNDS)]
    for n_fac in (2, 4):
        rows.append(run_hier(n_fac, commits))
    # WAN bandwidth sweep at 2 facilities: dcn default is 6.25 GB/s
    for bw in (0.625, 0.0625):
        rows.append(run_hier(2, commits, wan_GBps=bw))

    for r in rows:
        print(", ".join(f"{k}={v}" for k, v in r.items()))
    flat, h2 = rows[0], rows[1]
    payload = {
        "rows": rows,
        "wan_bytes_ratio_2fac": h2["wan_bytes"] / max(flat["wan_bytes"], 1),
        "accuracy_delta_2fac": h2["accuracy"] - flat["accuracy"],
        "local_rounds": LOCAL_ROUNDS,
        "clients": N_CLIENTS,
    }
    save("table_hierarchy", payload)
    print(f"saved: wan_bytes_ratio_2fac={payload['wan_bytes_ratio_2fac']:.4f} "
          f"accuracy_delta_2fac={payload['accuracy_delta_2fac']:+.4f}")


if __name__ == "__main__":
    main()
