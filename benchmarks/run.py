"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,...] [--rounds N]

Output: ``name,value,...`` CSV lines on stdout + JSON artifacts under
artifacts/bench/.  Roofline (from dry-run artifacts) is included when
artifacts/dryrun/ exists."""
from __future__ import annotations

import argparse
import os
import time
import traceback
from pathlib import Path

BENCHES = ["kernel_bench", "table2", "table3", "table4", "table_async",
           "table_sched_backend", "ablations", "roofline"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--rounds", type=int, default=0)
    args = ap.parse_args()
    if args.rounds:
        os.environ["REPRO_BENCH_ROUNDS"] = str(args.rounds)
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    from benchmarks import (ablations, kernel_bench, table2_accuracy,
                            table3_scalability, table4_communication,
                            table_async, table_sched_backend)
    jobs = {
        "kernel_bench": kernel_bench.main,
        "table2": table2_accuracy.main,
        "table3": table3_scalability.main,
        "table4": table4_communication.main,
        "table_async": table_async.main,
        "table_sched_backend": table_sched_backend.main,
        "ablations": ablations.main,
    }
    if Path("artifacts/dryrun").exists() and any(
            Path("artifacts/dryrun").glob("*.json")):
        from benchmarks import roofline
        jobs["roofline"] = lambda rounds=None: roofline.main()

    rc = 0
    for name in (only or BENCHES):
        fn = jobs.get(name)
        if fn is None:
            continue
        t0 = time.time()
        print(f"### bench:{name}")
        try:
            fn(rounds=args.rounds or None) if name != "roofline" else fn()
            print(f"### bench:{name} done in {time.time()-t0:.1f}s")
        except Exception:
            rc = 1
            print(f"### bench:{name} FAILED")
            traceback.print_exc()
    raise SystemExit(rc)


if __name__ == "__main__":
    main()
