"""Analytic FLOP/byte cost model per (arch x shape) — the compute and memory
roofline terms.

Why analytic: XLA's HloCostAnalysis on the AOT-compiled module counts while
bodies ONCE (verified empirically — flops are invariant to lax.scan trip
count), so with scan-over-layers/clients/steps the reported FLOPs understate
the true work by the loop trip counts.  We therefore:

  * derive compute/memory terms from exact per-layer formulas below
    (validated against cost_analysis on an --unroll build, see
    EXPERIMENTS.md §Roofline validation),
  * take the COLLECTIVE term from the partitioned HLO text with while
    trip-count multipliers (launch/dryrun.py::collective_bytes).

Conventions: matmul [m,k]x[k,n] = 2mkn FLOPs; bwd = 2x fwd; remat (per-group
jax.checkpoint) adds ~1 extra fwd -> train factor 4.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig
from repro.models.transformer import block_pattern

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
DCN_BW = 6.25e9              # bytes/s cross-pod (50 Gb/s class WAN/DCN)


def mamba_dims(cfg: ModelConfig):
    di = cfg.mamba.expand * cfg.d_model
    R = cfg.mamba.dt_rank or math.ceil(cfg.d_model / 16)
    return di, R, cfg.mamba.d_state


def layer_param_bytes(cfg: ModelConfig, slot) -> float:
    """Parameter bytes of one layer slot."""
    D, F = cfg.d_model, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    n = 0
    if slot.mixer in ("attn", "cross"):
        n += D * (H + 2 * KV) * hd + H * hd * D
    elif slot.mixer == "mamba":
        di, R, N = mamba_dims(cfg)
        n += D * 2 * di + cfg.mamba.d_conv * di + di * (R + 2 * N) \
            + R * di + di * N + 2 * di + di * D
    elif slot.mixer == "mlstm":
        du = int(cfg.xlstm.proj_factor * D)
        n += D * 2 * du + 3 * du * du + 2 * du * cfg.n_heads + du * D
    elif slot.mixer == "slstm":
        hd_s = D // cfg.n_heads
        n += 4 * (D * D + cfg.n_heads * hd_s * hd_s + D) + D * D
    if slot.ffn == "mlp":
        mats = 3 if cfg.act in ("swiglu", "geglu") else 2
        n += mats * D * F
    elif slot.ffn == "moe":
        mats = 3 if cfg.act in ("swiglu", "geglu") else 2
        n += D * cfg.moe.num_experts \
            + cfg.moe.num_experts * mats * D * cfg.moe.d_expert
    return n * bpe


def model_param_bytes(cfg: ModelConfig) -> float:
    pattern = block_pattern(cfg)
    G = cfg.n_layers // len(pattern)
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    total = G * sum(layer_param_bytes(cfg, s) for s in pattern)
    ncb = max(cfg.n_codebooks, 1)
    total += ncb * cfg.vocab * cfg.d_model * bpe          # embed
    total += cfg.d_model * ncb * cfg.vocab_padded * bpe   # unembed
    return total


def active_param_bytes(cfg: ModelConfig) -> float:
    """MoE: only top_k of num_experts active per token."""
    total = model_param_bytes(cfg)
    if cfg.moe is None:
        return total
    pattern = block_pattern(cfg)
    G = cfg.n_layers // len(pattern)
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    mats = 3 if cfg.act in ("swiglu", "geglu") else 2
    expert_bytes = (G * sum(1 for s in pattern if s.ffn == "moe")
                    * cfg.moe.num_experts * mats * cfg.d_model
                    * cfg.moe.d_expert * bpe)
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return total - expert_bytes * (1 - frac)


def layer_fwd_flops_per_token(cfg: ModelConfig, slot, ctx: float,
                              n_patches: int = 0) -> float:
    D, F = cfg.d_model, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    f = 0.0
    if slot.mixer == "attn":
        f += 2 * D * (H + 2 * KV) * hd          # qkv
        f += 2 * ctx * H * hd * 2               # scores + AV
        f += 2 * H * hd * D                     # out proj
    elif slot.mixer == "cross":
        f += 2 * D * H * hd * 2                 # q + out
        f += 2 * n_patches * H * hd * 2         # cross scores + AV
        # kv over patches amortised per token: patches*2*KV*hd*D / seq — small,
        # folded into the scores term for simplicity.
    elif slot.mixer == "mamba":
        di, R, N = mamba_dims(cfg)
        f += 4 * D * di + 2 * cfg.mamba.d_conv * di + 2 * di * (R + 2 * N) \
            + 2 * R * di + 12 * di * N + 2 * di * D + 8 * di
    elif slot.mixer == "mlstm":
        du = int(cfg.xlstm.proj_factor * D)
        hdu = du // cfg.n_heads
        L = cfg.xlstm.chunk
        f += 4 * D * du + 3 * 2 * du * du + 4 * du * cfg.n_heads
        f += 2 * L * du * 2                     # intra-chunk attn
        f += 2 * du * hdu * 3                   # state query/update
        f += 2 * du * D
    elif slot.mixer == "slstm":
        hd_s = D // cfg.n_heads
        f += 4 * 2 * D * D + 8 * D * hd_s + 30 * D + 2 * D * D
    if slot.ffn == "mlp":
        mats = 3 if cfg.act in ("swiglu", "geglu") else 2
        f += mats * 2 * D * F
    elif slot.ffn == "moe":
        mats = 3 if cfg.act in ("swiglu", "geglu") else 2
        f += 2 * D * cfg.moe.num_experts
        f += cfg.moe.top_k * mats * 2 * D * cfg.moe.d_expert
    return f


def fwd_flops(cfg: ModelConfig, n_tokens: float, ctx: float) -> float:
    pattern = block_pattern(cfg)
    G = cfg.n_layers // len(pattern)
    per_tok = G * sum(layer_fwd_flops_per_token(cfg, s, ctx, cfg.n_patches)
                      for s in pattern)
    ncb = max(cfg.n_codebooks, 1)
    per_tok += 2 * cfg.d_model * ncb * cfg.vocab_padded   # unembed
    return per_tok * n_tokens


@dataclass
class Costs:
    flops: float             # total FLOPs of the lowered step (global)
    hbm_bytes: float         # total HBM traffic (global)
    model_flops: float       # 6*N_active*tokens reference
    tokens: float
    param_bytes: float
    active_param_bytes: float


def step_costs(arch: str, shape_name: str, clients: int = 0,
               local_steps: int = 1) -> Costs:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    S, B = shape.seq_len, shape.global_batch
    pb = model_param_bytes(cfg)
    apb = active_param_bytes(cfg)
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    n_active = apb / bpe

    if shape.kind == "train":
        from repro.launch.dryrun import PARALLEL_ARCHS
        C = clients or (16 if arch in PARALLEL_ARCHS else 4)
        H = local_steps
        tokens_per_step = B // C * S * 1.0    # per client per local step
        ctx = min(cfg.sliding_window or S, S) if cfg.sliding_window else (S + 1) / 2
        f1 = fwd_flops(cfg, tokens_per_step, ctx)
        flops = C * H * 4.0 * f1              # fwd + remat-fwd + 2x bwd
        flops += C * 40.0 * (pb / bpe)        # delta compress (quantize f32)
        flops += 4.0 * (pb / bpe)             # server apply
        tokens = C * H * tokens_per_step
        # HBM: per client-step: params fwd + remat + bwd reads + grad writes
        act_traffic = 8 * tokens_per_step * cfg.d_model * bpe * cfg.n_layers
        hbm = C * H * (4 * pb + act_traffic) + 3 * C * pb   # delta accum r/w
        return Costs(flops, hbm, 6 * n_active * tokens, tokens, pb, apb)

    # inference reference is forward-only: MODEL_FLOPS = 2*N_active*tokens
    if shape.kind == "prefill":
        ctx = min(cfg.sliding_window or S, S) if cfg.sliding_window else (S + 1) / 2
        tokens = B * S * 1.0
        flops = fwd_flops(cfg, tokens, ctx)
        act = 4 * tokens * cfg.d_model * bpe * cfg.n_layers
        cache = cache_bytes(cfg, B, S)
        return Costs(flops, pb + act + cache, 2 * n_active * tokens, tokens,
                     pb, apb)

    # decode: one token per sequence
    ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S
    tokens = B * 1.0
    flops = fwd_flops(cfg, tokens, ctx)
    # decode HBM: active params + full KV/state cache read + slot write
    hbm = decode_active_bytes(cfg, B) + cache_bytes(cfg, B, S)
    return Costs(flops, hbm, 2 * n_active * tokens, tokens, pb, apb)


def cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    pattern = block_pattern(cfg)
    G = cfg.n_layers // len(pattern)
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    S_c = min(cfg.sliding_window, S) if cfg.sliding_window else S
    total = 0.0
    for s in pattern:
        if s.mixer == "attn":
            total += G * B * S_c * 2 * cfg.kv_heads * cfg.hd * bpe
        elif s.mixer == "cross":
            total += G * B * cfg.n_patches * 2 * cfg.kv_heads * cfg.hd * bpe
        elif s.mixer == "mamba":
            di, R, N = mamba_dims(cfg)
            total += G * B * di * (N * 4 + (cfg.mamba.d_conv - 1) * bpe)
        elif s.mixer == "mlstm":
            du = int(cfg.xlstm.proj_factor * cfg.d_model)
            hdu = du // cfg.n_heads
            total += G * B * cfg.n_heads * (hdu * hdu + hdu + 1) * 4
        elif s.mixer == "slstm":
            total += G * B * cfg.d_model * 4 * 4
    return total


def decode_active_bytes(cfg: ModelConfig, B: int) -> float:
    """Weight bytes read for one decode step: non-expert params + the expert
    weights actually routed to (bounded by B*top_k distinct experts)."""
    apb_full = model_param_bytes(cfg)
    if cfg.moe is None:
        return apb_full
    expert_frac = min(1.0, B * cfg.moe.top_k / cfg.moe.num_experts)
    act = active_param_bytes(cfg)
    # interpolate between active-only and full depending on batch coverage
    return act + (apb_full - act) * expert_frac


def commit_bytes_touched(n_elems: float, n_slots: int, *,
                         quantize_bits: int = 0, topk: bool = False,
                         secure: bool = False, fused: bool = False) -> float:
    """HBM bytes touched by the server-side commit (the
    compress -> weight/discount -> (mask) -> accumulate stack) over K slot
    deltas of n_elems float32 each.

    fused (core.pipeline use_fused): every slot leaf is read once and the
    reduced leaf written once — 4*K*n read + 4*n write — regardless of how
    many logical stages run inside the kernel.

    unfused: each enabled stage materialises a full [K, n] float32
    intermediate (read + write = 8*K*n), then the aggregate reads the stack
    once more and writes the sum.  Stages: weight/discount scale (always),
    top-k, quantize, secure mask-add."""
    K, n = n_slots, float(n_elems)
    if fused:
        return 4.0 * K * n + 4.0 * n
    stages = 1 + bool(topk) + bool(quantize_bits) + bool(secure)
    return stages * 8.0 * K * n + (4.0 * K * n + 4.0 * n)


def roofline_terms(arch: str, shape_name: str, n_chips: int,
                   collective_bytes_per_device: float,
                   clients: int = 0, local_steps: int = 1) -> dict:
    c = step_costs(arch, shape_name, clients, local_steps)
    compute_s = c.flops / (n_chips * PEAK_FLOPS)
    memory_s = c.hbm_bytes / (n_chips * HBM_BW)
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    commit = {}
    if INPUT_SHAPES[shape_name].kind == "train":
        cfg = get_config(arch)
        bpe = 2 if cfg.dtype == "bfloat16" else 4
        n_elems = c.param_bytes / bpe
        from repro.launch.dryrun import PARALLEL_ARCHS
        K = clients or (16 if arch in PARALLEL_ARCHS else 4)
        unf = commit_bytes_touched(n_elems, K, quantize_bits=8, topk=True,
                                   secure=True)
        fus = commit_bytes_touched(n_elems, K, quantize_bits=8, topk=True,
                                   secure=True, fused=True)
        commit = {"commit_bytes_unfused": unf, "commit_bytes_fused": fus,
                  "commit_fused_x": fus / unf,
                  "commit_memory_s_unfused": unf / (n_chips * HBM_BW),
                  "commit_memory_s_fused": fus / (n_chips * HBM_BW)}
    return {
        **terms, **commit,
        "dominant": dom.replace("_s", ""),
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "model_flops": c.model_flops,
        "useful_ratio": c.model_flops / c.flops if c.flops else 0.0,
        "tokens": c.tokens,
        "param_bytes": c.param_bytes,
        "active_param_bytes": c.active_param_bytes,
        "bytes_per_device": c.param_bytes / n_chips,
    }
