"""Microbenchmarks of the Pallas kernels (interpret mode on CPU — wall times
are NOT TPU times; the derived column reports bytes touched per call so the
HBM-bound roofline expectation on TPU is visible)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from benchmarks.common import save


def timeit(fn, *args, n=7):
    """(median us/call over n repeats, kernel launches per call).

    One warmup call absorbs compile + first dispatch — and, being the
    fresh trace of the (per-callsite) jit closure, is where kernels/ops'
    call-time launch counter fires, so it doubles as the launch count per
    logical call.  Each timed repeat is individually fenced with
    ``jax.block_until_ready`` (it walks tuples/pytrees) and the MEDIAN is
    reported: single-warmup means are easily skewed by one GC pause or
    lazy-allocation hiccup on the shared CI boxes."""
    ops.KERNEL_LAUNCHES = 0
    jax.block_until_ready(fn(*args))
    launches = ops.KERNEL_LAUNCHES
    reps = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        reps.append(time.perf_counter() - t0)
    return float(np.median(reps)) * 1e6, launches      # us


def _row(name, timing, nbytes):
    us, launches = timing
    row = {"name": name, "us_per_call": us, "bytes_touched": int(nbytes),
           "launches_per_call": launches,
           "derived_GBps_touched": nbytes / us / 1e3}
    print(f"kernel,{name},{us:.0f}us,{launches}launch,"
          f"{nbytes/us/1e3:.2f}GB/s-touched")
    return row


def main(rounds=None):
    rng = np.random.default_rng(0)
    n = 1 << 20
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    rows = []
    for name, fn, nbytes in [
        ("quantize_int8", jax.jit(lambda a: ops.quantize_dequant(a, bits=8)),
         n * 8),
        ("quantize_ref", jax.jit(lambda a: ref.quantize_dequant_ref(a, 8)),
         n * 8),
        ("topk_sparsify", jax.jit(lambda a: ops.topk_sparsify(a, k=26)),
         n * 8),
        ("topk_ref", jax.jit(lambda a: ref.topk_sparsify_ref(a, 26)), n * 8),
        ("fedprox_update",
         jax.jit(lambda a: ops.fedprox_update(a, a, a, lr=0.1, mu=0.01)),
         n * 16),
    ]:
        rows.append(_row(name, timeit(lambda: fn(x)), nbytes))

    # fused commit kernels: K slot deltas in, one accumulated block out.
    # One HBM pass over the slot tensors (4*K*n read + 4*n write) replaces
    # the unfused weight/topk/quantize/sum stage stack.
    K, nf = 4, 1 << 18
    xs = jnp.asarray(rng.normal(size=(K, nf)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 1, K).astype(np.float32))
    s = jnp.asarray(rng.integers(0, 4, K).astype(np.float32))
    fused_bytes = 4 * K * nf + 4 * nf
    rows.append(_row(
        "fused_accum",
        timeit(jax.jit(lambda a: ops.fused_accum(a, w, s, 0.5)), xs),
        fused_bytes))
    rows.append(_row(
        "fused_plain_commit",
        timeit(jax.jit(lambda a: ops.fused_plain_commit(
            a, w, s, 0.5, bits=8, k=26)), xs),
        fused_bytes))
    ids = jnp.arange(1, K + 1, dtype=jnp.uint32)
    from repro.core import secure_agg as sec
    seeds = sec.pair_seeds(jax.random.PRNGKey(0), ids)
    coef = sec.pair_coef_int(ids, jnp.ones((K,), jnp.float32))
    rows.append(_row(
        "fused_secure_commit",
        timeit(jax.jit(lambda a: ops.fused_secure_commit(
            a, w, seeds, coef, 0, bits=8)), xs),
        fused_bytes))

    # leaf bucketing: a many-leaf tree committed through the bucketed tree
    # entry point (one launch) vs one kernel call per leaf — the dispatch
    # collapse core/pipeline.py relies on.  Same total elements both ways.
    n_leaves = 24
    leaf_shapes = [(K, 1 << (10 + i % 5)) for i in range(n_leaves)]
    leaves = [jnp.asarray(rng.normal(size=shp).astype(np.float32))
              for shp in leaf_shapes]
    tree_bytes = sum(4 * l.size + 4 * l.size // K for l in leaves)
    rows.append(_row(
        f"fused_plain_bucketed_{n_leaves}leaves",
        timeit(jax.jit(lambda ls: ops.fused_plain_commit_tree(
            ls, w, s, 0.5, bits=8, k=26)), leaves),
        tree_bytes))
    rows.append(_row(
        f"fused_plain_per_leaf_{n_leaves}leaves",
        timeit(jax.jit(lambda ls: [ops.fused_plain_commit(
            l, w, s, 0.5, bits=8, k=26) for l in ls]), leaves),
        tree_bytes))

    B, L, D, N = 4, 128, 1024, 16
    a = jnp.asarray(rng.uniform(0.5, 1, (B, L, D, N)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(B, L, D, N)).astype(np.float32))
    h0 = jnp.zeros((B, D, N), jnp.float32)
    for name, fn in [("selective_scan_kernel", ops.selective_scan_chunk),
                     ("selective_scan_ref", ref.selective_scan_chunk_ref)]:
        jfn = jax.jit(fn)
        rows.append(_row(name, timeit(lambda: jfn(a, b, h0)), a.nbytes * 3))
    save("kernel_bench", {"rows": rows,
                          "note": "interpret-mode CPU walltimes, not TPU"})
    return rows


if __name__ == "__main__":
    main()
