"""Microbenchmarks of the Pallas kernels (interpret mode on CPU — wall times
are NOT TPU times; the derived column reports bytes touched per call so the
HBM-bound roofline expectation on TPU is visible)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from benchmarks.common import save


def timeit(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n * 1e6      # us


def main(rounds=None):
    rng = np.random.default_rng(0)
    n = 1 << 20
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    rows = []
    for name, fn, nbytes in [
        ("quantize_int8", jax.jit(lambda a: ops.quantize_dequant(a, bits=8)),
         n * 8),
        ("quantize_ref", jax.jit(lambda a: ref.quantize_dequant_ref(a, 8)),
         n * 8),
        ("topk_sparsify", jax.jit(lambda a: ops.topk_sparsify(a, k=26)),
         n * 8),
        ("topk_ref", jax.jit(lambda a: ref.topk_sparsify_ref(a, 26)), n * 8),
        ("fedprox_update",
         jax.jit(lambda a: ops.fedprox_update(a, a, a, lr=0.1, mu=0.01)),
         n * 16),
    ]:
        us = timeit(lambda: fn(x))
        rows.append({"name": name, "us_per_call": us,
                     "derived_GBps_touched": nbytes / us / 1e3})
        print(f"kernel,{name},{us:.0f}us,{nbytes/us/1e3:.2f}GB/s-touched")
    B, L, D, N = 4, 128, 1024, 16
    a = jnp.asarray(rng.uniform(0.5, 1, (B, L, D, N)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(B, L, D, N)).astype(np.float32))
    h0 = jnp.zeros((B, D, N), jnp.float32)
    for name, fn in [("selective_scan_kernel", ops.selective_scan_chunk),
                     ("selective_scan_ref", ref.selective_scan_chunk_ref)]:
        jfn = jax.jit(fn)
        us = timeit(lambda: jfn(a, b, h0))
        nbytes = a.nbytes * 3
        rows.append({"name": name, "us_per_call": us,
                     "derived_GBps_touched": nbytes / us / 1e3})
        print(f"kernel,{name},{us:.0f}us,{nbytes/us/1e3:.2f}GB/s-touched")
    save("kernel_bench", {"rows": rows,
                          "note": "interpret-mode CPU walltimes, not TPU"})
    return rows


if __name__ == "__main__":
    main()
