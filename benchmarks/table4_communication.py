"""Paper Table 4: per-round communication volume with/without compression
(paper: ~43-45 MB -> ~14-16 MB, ~65% reduction, over 10 rounds).

We run 10 real rounds with byte-exact payload accounting under 8-bit
quantization + top-30% sparsification.  The reproduced claim is the ~65%
volume reduction at negligible accuracy cost; absolute MB scales with the
model (the paper's is a larger CNN than our CPU-budget one — Table 4 reports
per-client upload MB per round for both)."""
from __future__ import annotations

import numpy as np

from repro.core import CompressionConfig
from benchmarks.common import run_fl, save


def main(rounds: int = None):
    rounds = rounds or 10
    comp = CompressionConfig(quantize_bits=8, topk_frac=0.30)
    res_plain = run_fl("cifar10", rounds=rounds, seed=7)
    res_comp = run_fl("cifar10", rounds=rounds, seed=7, compression=comp)

    orch_p, orch_c = res_plain["orch"], res_comp["orch"]
    rows = []
    bpr_p, np_p = orch_p.comm.bytes_per_round("up"), orch_p.comm.participants_per_round()
    bpr_c, np_c = orch_c.comm.bytes_per_round("up"), orch_c.comm.participants_per_round()
    for r in range(rounds):
        plain = bpr_p.get(r, 0) / max(np_p.get(r, 1), 1) / 1e6
        compd = bpr_c.get(r, 0) / max(np_c.get(r, 1), 1) / 1e6
        rows.append({"round": r + 1,
                     "no_compression_MB": round(plain, 3),
                     "with_compression_MB": round(compd, 3)})
        print(f"table4,round={r+1},plain={rows[-1]['no_compression_MB']},"
              f"comp={rows[-1]['with_compression_MB']}")
    red = 1 - np.mean([r["with_compression_MB"] for r in rows]) / \
        max(np.mean([r["no_compression_MB"] for r in rows]), 1e-9)
    print(f"table4,reduction={red:.1%},acc_plain={res_plain['final_acc']:.3f},"
          f"acc_comp={res_comp['final_acc']:.3f}")
    save("table4_communication", {
        "rows": rows, "reduction": red,
        "acc_plain": res_plain["final_acc"], "acc_comp": res_comp["final_acc"],
        "paper_reduction": 0.65})
    return rows


if __name__ == "__main__":
    main()
