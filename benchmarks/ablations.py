"""Paper §5.5 ablations — disable one optimization at a time:
  * no adaptive client selection  -> +12% average round duration (paper)
  * no communication compression  -> +70% bandwidth usage (paper; inverse of
                                     the ~65%/Table-4 reduction: 1/0.35-ish)
  * no straggler mitigation       -> 15-20% longer time-to-accuracy (paper)
Plus the fault-tolerance claim (§5.4): 20% dropout -> <1.8pp accuracy loss.
"""
from __future__ import annotations

import numpy as np

from repro.core import CompressionConfig
from repro.orchestrator import FaultConfig, StragglerPolicy
from benchmarks.common import ROUNDS, run_fl, save


def main(rounds: int = None):
    rounds = rounds or ROUNDS
    comp = CompressionConfig(quantize_bits=8, topk_frac=0.10)
    strag = StragglerPolicy(fastest_k=6, contention_sigma=0.3)
    base = run_fl("cifar10", rounds=rounds, compression=comp, straggler=strag,
                  selection="adaptive", seed=11)

    # --- no adaptive selection -------------------------------------------
    rand_sel = run_fl("cifar10", rounds=rounds, compression=comp,
                      straggler=strag, selection="random", seed=11)
    round_time_increase = (rand_sel["mean_round_s"] / base["mean_round_s"]) - 1

    # --- no compression ---------------------------------------------------
    no_comp = run_fl("cifar10", rounds=rounds, straggler=strag,
                     selection="adaptive", seed=11)
    bw_increase = (no_comp["bytes_per_client_round"] /
                   base["bytes_per_client_round"]) - 1

    # --- no straggler mitigation (time to reach target accuracy) ----------
    no_strag = run_fl("cifar10", rounds=rounds, compression=comp,
                      straggler=StragglerPolicy(contention_sigma=0.3),
                      selection="adaptive", seed=11)

    def time_to_acc(res, target):
        logs = res["orch"].logs
        t = 0.0
        for l in logs:
            t += l.duration_s
            if np.isfinite(l.eval_metric) and l.eval_metric >= target:
                return t
        return t  # never reached: full duration (lower bound)

    target = min(0.8 * base["final_acc"], 0.6)
    t_with = time_to_acc(base, target)
    t_without = time_to_acc(no_strag, target)
    strag_increase = (t_without / max(t_with, 1e-9)) - 1

    # --- fault tolerance (§5.4) -------------------------------------------
    dropped = run_fl("cifar10", rounds=rounds, compression=comp,
                     straggler=strag, selection="adaptive", seed=11,
                     faults=FaultConfig(dropout_prob=0.2))
    acc_drop_pp = (base["final_acc"] - dropped["final_acc"]) * 100

    out = {
        "no_adaptive_selection_round_time_increase": round_time_increase,
        "no_compression_bandwidth_increase": bw_increase,
        "no_straggler_mitigation_time_increase": strag_increase,
        "dropout20_accuracy_loss_pp": acc_drop_pp,
        "paper": {"selection": 0.12, "compression": 0.70,
                  "straggler": (0.15, 0.20), "dropout_pp": 1.8},
        "final_accs": {"base": base["final_acc"],
                       "random_sel": rand_sel["final_acc"],
                       "no_comp": no_comp["final_acc"],
                       "no_strag": no_strag["final_acc"],
                       "dropout20": dropped["final_acc"]},
    }
    for k, v in out.items():
        if isinstance(v, float):
            print(f"ablation,{k},{v:.4f}")
    save("ablations", out)
    return out


if __name__ == "__main__":
    main()
