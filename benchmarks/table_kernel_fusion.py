"""Fused-commit roofline table (ISSUE 7 acceptance artifact).

Measures the one-pass Pallas commit path (core.pipeline, use_fused) against
the unfused stage stack per (leaf-size x quantize-bits x secure_agg) cell:

  * achieved parity          max |fused - unfused| on the committed delta
  * wall time fused/unfused  CPU interpret-mode walltimes — NOT TPU times;
                             the bytes columns carry the roofline claim
  * predicted bytes-touched  costmodel.commit_bytes_touched fused vs the
                             per-stage unfused stack (acceptance: <= 0.5x)
  * masked wire bytes        secure_agg.masked_payload_bytes vs the plain
                             quantized payload (acceptance: 8-bit masked
                             within 1.25x of plain — the integer-domain
                             masking collapse of the historical ~3.9x)

Two extra sections ride along (PR 10):

  * bucketing      launches per commit on a many-leaf tree: the bucketed
                   tree entry points (kernels/ops.fused_*_tree, what
                   core/pipeline dispatches) vs one kernel call per leaf
                   (acceptance: O(#buckets), i.e. independent of #leaves)
  * sharded        the same fused-vs-unfused parity under an active
                   2-device GSPMD mesh — UpdatePipeline.fused must stay
                   True and parity hold now that the kernels shard_map
                   themselves over the mesh

Run:  PYTHONPATH=src:. python benchmarks/table_kernel_fusion.py
"""
from __future__ import annotations

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    # the sharded section needs >= 2 devices; must be set before the jax
    # backend initializes (harmless no-op if something already booted it —
    # the section then skips itself)
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from benchmarks.costmodel import commit_bytes_touched
from repro.core.compression import CompressionConfig, payload_bytes
from repro.core.round import FLConfig
from repro.core.pipeline import build_update_pipeline
from repro.core.secure_agg import masked_payload_bytes
from repro.kernels import ops as kops
from repro.models import sharding as sh

K = 4                                   # commit slots (async buffer size)
LEAF_SIZES = [1 << 16, 1 << 20]
BITS = [4, 8]


def _time(fn, *args, n=5):
    # median of n fenced repeats after one warmup (compile + dispatch);
    # the median resists the one-off GC/allocation hiccups that skew a
    # mean on shared CI boxes
    jax.block_until_ready(fn(*args))
    reps = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        reps.append(time.perf_counter() - t0)
    return float(np.median(reps))


def _launches(fn, *args):
    """Kernel launches in one fresh trace of fn (kernels/ops counts at
    call time, i.e. while the jit traces)."""
    kops.KERNEL_LAUNCHES = 0
    jax.block_until_ready(fn(*args))
    return kops.KERNEL_LAUNCHES


def _cell(n_elems, bits, secure, rng):
    comp = CompressionConfig(quantize_bits=bits, topk_frac=0.1,
                             stochastic_rounding=False)
    # magnitudes constructed distinct: an exact float32 tie at the k-th
    # top-k boundary is the one place sort-based (unfused) and threshold
    # -based (kernel) selection legitimately differ, and 2^20 normal draws
    # collide on the float32 grid often enough to hit it
    mags = np.linspace(1e-3, 1.0, n_elems, dtype=np.float64)
    signs = rng.choice([-1.0, 1.0], n_elems)
    tree = {"w": jnp.asarray((rng.permutation(mags) * signs * 0.01)
                             .astype(np.float32))}
    deltas = {"w": jnp.stack([tree["w"] * (i + 1) * 0.5 for i in range(K)])}
    weights = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    mask = jnp.ones((K,), jnp.float32)
    staleness = jnp.asarray(rng.integers(0, 4, K).astype(np.float32))
    key = jax.random.PRNGKey(0)

    def build(use_fused):
        cfg = FLConfig(secure_agg=secure, compression=dataclasses.replace(
            comp, use_fused=use_fused))
        pipe = build_update_pipeline(cfg)

        @jax.jit
        def commit(d, w, m, s, r):
            summed, _, w_raw = pipe.combine_unnormalised(
                d, w, m, None, r, staleness=s, exponent=0.5)
            return pipe.normalise(summed, w_raw.sum())
        return commit

    fused, unfused = build(True), build(False)
    args = (deltas, weights, mask, staleness, key)
    launches = _launches(fused, *args)
    t_f, t_u = _time(fused, *args), _time(unfused, *args)
    diff = float(jnp.max(jnp.abs(fused(*args)["w"] - unfused(*args)["w"])))

    pred_f = commit_bytes_touched(n_elems, K, quantize_bits=bits, topk=True,
                                  secure=secure, fused=True)
    pred_u = commit_bytes_touched(n_elems, K, quantize_bits=bits, topk=True,
                                  secure=secure)
    # wire baseline is the DENSE quantized payload: masking ships dense
    # finite-ring words, so sparsity never survives the masked wire and the
    # honest comparison is masked ring words vs plain quantized words
    quant_only = dataclasses.replace(comp, topk_frac=0.0)
    plain_wire = payload_bytes(tree, quant_only)
    masked_wire = masked_payload_bytes(tree, quant_only, n_slots=K)
    return {
        "n_elems": n_elems, "bits": bits, "secure": secure,
        "fused_s": t_f, "unfused_s": t_u,
        "walltime_fused_x": t_f / t_u,
        "launches_fused": launches,
        "fused_vs_unfused_max_abs": diff,
        "pred_bytes_fused": pred_f, "pred_bytes_unfused": pred_u,
        "pred_bytes_fused_x": pred_f / pred_u,
        "plain_quant_wire_bytes": plain_wire,
        "masked_wire_bytes": masked_wire,
        "masked_wire_x": masked_wire / plain_wire,
    }


def _bucketing_row(rng, n_leaves=32):
    """Launches per commit on a many-leaf tree: the bucketed pipeline path
    vs one kernel call per leaf (the pre-bucketing dispatch pattern)."""
    leaves = [jnp.asarray(rng.normal(size=(K, 1 << (8 + i % 6)))
                          .astype(np.float32) * 0.01)
              for i in range(n_leaves)]
    w = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    s = jnp.asarray(rng.integers(0, 4, K).astype(np.float32))
    bucketed = jax.jit(lambda ls: kops.fused_plain_commit_tree(
        ls, w, s, 0.5, bits=8, k=26))
    per_leaf = jax.jit(lambda ls: [kops.fused_plain_commit(
        l, w, s, 0.5, bits=8, k=26) for l in ls])
    l_b, l_p = _launches(bucketed, leaves), _launches(per_leaf, leaves)
    t_b, t_p = _time(bucketed, leaves), _time(per_leaf, leaves)
    parity = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(bucketed(leaves), per_leaf(leaves)))
    row = {"n_leaves": n_leaves, "launches_bucketed": l_b,
           "launches_per_leaf": l_p, "bucketed_s": t_b, "per_leaf_s": t_p,
           "bucketed_vs_per_leaf_max_abs": parity}
    print(f"bucketing: {n_leaves} leaves -> {l_b} launch(es) bucketed vs "
          f"{l_p} per-leaf, parity={parity:.2e}")
    return row


def _sharded_rows(rng):
    """Fused-vs-unfused commit parity with an ACTIVE 2-device mesh: the
    gate-lift acceptance — UpdatePipeline.fused stays True and the
    shard_mapped kernels match the unfused GSPMD lowering."""
    if len(jax.devices()) < 2:
        print("sharded: skipped (single device; jax initialized before "
              "the device-count flag could apply)")
        return []
    mesh = jax.make_mesh((2,), ("data",))
    out = []
    for secure in (False, True):
        comp = CompressionConfig(quantize_bits=8, topk_frac=0.1,
                                 stochastic_rounding=False)
        deltas = {"w": jnp.asarray(
            rng.normal(size=(K, 1 << 16)).astype(np.float32) * 0.01)}
        weights = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
        mask = jnp.ones((K,), jnp.float32)
        key = jax.random.PRNGKey(0)
        with sh.use_mesh(mesh):
            def build(use_fused):
                cfg = FLConfig(secure_agg=secure,
                               compression=dataclasses.replace(
                                   comp, use_fused=use_fused))
                pipe = build_update_pipeline(cfg)

                @jax.jit
                def commit(d, w, m, r):
                    summed, _, w_raw = pipe.combine_unnormalised(
                        d, w, m, None, r)
                    return pipe.normalise(summed, w_raw.sum())
                return pipe, commit

            pipe_f, fused = build(True)
            _, unfused = build(False)
            assert pipe_f.fused, "gate-lift regression: fused off under mesh"
            args = (deltas, weights, mask, key)
            launches = _launches(fused, *args)
            t_f, t_u = _time(fused, *args), _time(unfused, *args)
            diff = float(jnp.max(jnp.abs(fused(*args)["w"]
                                         - unfused(*args)["w"])))
        out.append({"devices": 2, "mesh_axes": ["data"], "secure": secure,
                    "fused_stays_on": True, "launches_fused": launches,
                    "sharded_fused_s": t_f, "sharded_unfused_s": t_u,
                    "sharded_parity_max_abs": diff})
        print(f"sharded: secure={int(secure)} parity={diff:.2e} "
              f"launches={launches} (2-device mesh, fused stayed on)")
    return out


def main():
    rng = np.random.default_rng(0)
    rows = []
    for n in LEAF_SIZES:
        for bits in BITS:
            for secure in (False, True):
                r = _cell(n, bits, secure, rng)
                rows.append(r)
                print(f"n={n:>8d} bits={bits} secure={int(secure)} "
                      f"parity={r['fused_vs_unfused_max_abs']:.2e} "
                      f"bytes-fused={r['pred_bytes_fused_x']:.3f}x "
                      f"wire-masked={r['masked_wire_x']:.3f}x "
                      f"wall-fused={r['walltime_fused_x']:.2f}x "
                      f"launches={r['launches_fused']}")
    bucketing = _bucketing_row(rng)
    sharded = _sharded_rows(rng)
    headline = {
        "masked_wire_x_8bit": max(r["masked_wire_x"] for r in rows
                                  if r["bits"] == 8 and r["secure"]),
        "pred_bytes_fused_x_max": max(r["pred_bytes_fused_x"] for r in rows),
        "parity_max_abs": max(r["fused_vs_unfused_max_abs"] for r in rows),
        "launches_bucketed": bucketing["launches_bucketed"],
        "launches_per_leaf": bucketing["launches_per_leaf"],
        "sharded_parity_max_abs": max(
            (r["sharded_parity_max_abs"] for r in sharded), default=None),
    }
    print("headline:", headline)
    save("table_kernel_fusion", {
        "rows": rows, "bucketing": bucketing, "sharded": sharded,
        "headline": headline, "n_slots": K,
        "note": ("walltimes are CPU interpret-mode, not TPU; bytes columns "
                 "are the analytic roofline (costmodel.commit_bytes_touched) "
                 "and wire accounting (secure_agg.masked_payload_bytes)")})
    return rows


if __name__ == "__main__":
    main()
