"""Pytree payload serialization (wire format + checkpoint substrate).

Flat binary layout: a JSON header (paths, shapes, dtypes) + concatenated
raw little-endian array bytes.  Used by the checkpoint subsystem and for
exact wire-size accounting of uncompressed transfers."""
from __future__ import annotations

import io
import json

import jax
import numpy as np


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", k)) for k in path)
            for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def serialize_tree(tree) -> bytes:
    keys, leaves, _ = _paths(tree)
    arrays = [np.asarray(l) for l in leaves]
    header = {
        "keys": keys,
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [a.dtype.str for a in arrays],
    }
    hb = json.dumps(header).encode()
    buf = io.BytesIO()
    buf.write(len(hb).to_bytes(8, "little"))
    buf.write(hb)
    for a in arrays:
        buf.write(np.ascontiguousarray(a).tobytes())
    return buf.getvalue()


def deserialize_tree(data: bytes, like=None):
    n = int.from_bytes(data[:8], "little")
    header = json.loads(data[8:8 + n].decode())
    off = 8 + n
    arrays = []
    for shape, dtype in zip(header["shapes"], header["dtypes"]):
        dt = np.dtype(dtype)
        count = int(np.prod(shape)) if shape else 1
        nb = count * dt.itemsize
        arrays.append(np.frombuffer(data[off:off + nb], dt).reshape(shape))
        off += nb
    if like is not None:
        flat, treedef = jax.tree_util.tree_flatten(like)
        assert len(flat) == len(arrays), "structure mismatch"
        return jax.tree_util.tree_unflatten(treedef, arrays)
    return dict(zip(header["keys"], arrays))


def tree_bytes(tree) -> int:
    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(tree))
