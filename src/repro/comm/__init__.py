from repro.comm.transport import (  # noqa: F401
    CommAccountant, LinkClass, GRPC_CLOUD, MPI_HPC, ICI, DCN, LINKS,
    SITE_LINKS, WANTopology, link_for_site,
)
from repro.comm.payload import serialize_tree, deserialize_tree, tree_bytes  # noqa: F401
