"""Communication layer (paper §3.2): protocol abstraction + byte/time
accounting.

The paper's deployment uses gRPC (cloud) and MPI (HPC).  On the TPU target
the update transfer is an XLA collective, so this layer's runtime job is
*accounting and policy*: which link class a transfer crosses, what it costs,
and what the compression config saves — feeding Table 4 and the ablations.
The link classes mirror the paper's testbed plus the TPU fabric:

  grpc_cloud : cloud VM uplink    (~1 Gb/s, 10s of ms)
  mpi_hpc    : Infiniband         (~100 Gb/s, ~us)
  ici        : intra-pod TPU      (~50 GB/s/link)
  dcn        : cross-pod / WAN    (~6.25 GB/s, ms) — where hierarchical
               compressed aggregation applies.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class LinkClass:
    name: str
    bandwidth_GBps: float
    latency_s: float

    def transfer_time(self, nbytes: float) -> float:
        return self.latency_s + nbytes / (self.bandwidth_GBps * 1e9)


GRPC_CLOUD = LinkClass("grpc_cloud", 0.125, 0.020)
MPI_HPC = LinkClass("mpi_hpc", 12.5, 5e-6)
ICI = LinkClass("ici", 50.0, 1e-6)
DCN = LinkClass("dcn", 6.25, 1e-3)

LINKS = {l.name: l for l in (GRPC_CLOUD, MPI_HPC, ICI, DCN)}


# Explicit site→link table.  An unknown site is a configuration error and
# must fail loudly: the old fallback silently billed any typo'd site string
# at cloud latency, which skews every byte/time table it feeds.
SITE_LINKS = {
    "hpc": MPI_HPC,
    "cloud": GRPC_CLOUD,
}


def link_for_site(site: str) -> LinkClass:
    try:
        return SITE_LINKS[site]
    except KeyError:
        raise KeyError(
            f"unknown site {site!r}: no entry in SITE_LINKS "
            f"(known: {sorted(SITE_LINKS)})") from None


@dataclass
class WANTopology:
    """Per-facility-pair WAN link model for inter-facility transfers.

    Every pair defaults to the DCN class; `set_pair` overrides bandwidth /
    latency for a specific (symmetric) pair.  Jitter is an exponential tail
    added on top of the deterministic transfer time — the draw comes from
    the *caller's* RNG so hierarchical runs stay checkpoint-replayable.
    Link objects keep the name "dcn" regardless of per-pair overrides so
    accounting groups all WAN traffic under one link class.
    """
    default: LinkClass = DCN
    jitter_s: float = 0.0
    _pairs: dict = field(default_factory=dict)

    @staticmethod
    def _key(a: str, b: str) -> tuple[str, str]:
        return (a, b) if a <= b else (b, a)

    def set_pair(self, a: str, b: str, bandwidth_GBps: float | None = None,
                 latency_s: float | None = None) -> None:
        self._pairs[self._key(a, b)] = LinkClass(
            self.default.name,
            bandwidth_GBps if bandwidth_GBps is not None
            else self.default.bandwidth_GBps,
            latency_s if latency_s is not None else self.default.latency_s)

    def link(self, a: str, b: str) -> LinkClass:
        return self._pairs.get(self._key(a, b), self.default)

    def transfer_time(self, a: str, b: str, nbytes: float,
                      rng=None) -> float:
        t = self.link(a, b).transfer_time(nbytes)
        if self.jitter_s > 0.0 and rng is not None:
            t += float(rng.exponential(self.jitter_s))
        return t


@dataclass
class TransferRecord:
    rnd: int
    cid: int
    direction: str      # up | down | inter_facility
    nbytes: int
    link: str
    seconds: float


@dataclass
class CommAccountant:
    """Collects every logical transfer of a training run."""
    records: list = field(default_factory=list)

    def log(self, rnd: int, cid: int, direction: str, nbytes: int,
            link: LinkClass, seconds: float | None = None) -> float:
        """`seconds` overrides the link's deterministic transfer time —
        used by WANTopology callers that add jitter on their own RNG."""
        t = link.transfer_time(nbytes) if seconds is None else seconds
        self.records.append(TransferRecord(rnd, cid, direction, nbytes,
                                           link.name, t))
        return t

    def bytes_per_round(self, direction: str | None = None) -> dict[int, int]:
        out: dict[int, int] = {}
        for r in self.records:
            if direction and r.direction != direction:
                continue
            out[r.rnd] = out.get(r.rnd, 0) + r.nbytes
        return out

    def participants_per_round(self, direction: str = "up") -> dict[int, int]:
        out: dict[int, int] = {}
        for r in self.records:
            if r.direction == direction:
                out[r.rnd] = out.get(r.rnd, 0) + 1
        return out

    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.records)

    def mean_bytes_per_client_round(self) -> float:
        ups = [r for r in self.records if r.direction == "up"]
        if not ups:
            return 0.0
        rounds = len({r.rnd for r in ups})
        clients = max(len({r.cid for r in ups}), 1)
        return sum(r.nbytes for r in ups) / max(rounds, 1) / clients
