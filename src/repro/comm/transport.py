"""Communication layer (paper §3.2): protocol abstraction + byte/time
accounting.

The paper's deployment uses gRPC (cloud) and MPI (HPC).  On the TPU target
the update transfer is an XLA collective, so this layer's runtime job is
*accounting and policy*: which link class a transfer crosses, what it costs,
and what the compression config saves — feeding Table 4 and the ablations.
The link classes mirror the paper's testbed plus the TPU fabric:

  grpc_cloud : cloud VM uplink    (~1 Gb/s, 10s of ms)
  mpi_hpc    : Infiniband         (~100 Gb/s, ~us)
  ici        : intra-pod TPU      (~50 GB/s/link)
  dcn        : cross-pod / WAN    (~6.25 GB/s, ms) — where hierarchical
               compressed aggregation applies.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class LinkClass:
    name: str
    bandwidth_GBps: float
    latency_s: float

    def transfer_time(self, nbytes: float) -> float:
        return self.latency_s + nbytes / (self.bandwidth_GBps * 1e9)


GRPC_CLOUD = LinkClass("grpc_cloud", 0.125, 0.020)
MPI_HPC = LinkClass("mpi_hpc", 12.5, 5e-6)
ICI = LinkClass("ici", 50.0, 1e-6)
DCN = LinkClass("dcn", 6.25, 1e-3)

LINKS = {l.name: l for l in (GRPC_CLOUD, MPI_HPC, ICI, DCN)}


def link_for_site(site: str) -> LinkClass:
    return MPI_HPC if site == "hpc" else GRPC_CLOUD


@dataclass
class TransferRecord:
    rnd: int
    cid: int
    direction: str      # up | down
    nbytes: int
    link: str
    seconds: float


@dataclass
class CommAccountant:
    """Collects every logical transfer of a training run."""
    records: list = field(default_factory=list)

    def log(self, rnd: int, cid: int, direction: str, nbytes: int,
            link: LinkClass) -> float:
        t = link.transfer_time(nbytes)
        self.records.append(TransferRecord(rnd, cid, direction, nbytes,
                                           link.name, t))
        return t

    def bytes_per_round(self, direction: str | None = None) -> dict[int, int]:
        out: dict[int, int] = {}
        for r in self.records:
            if direction and r.direction != direction:
                continue
            out[r.rnd] = out.get(r.rnd, 0) + r.nbytes
        return out

    def participants_per_round(self, direction: str = "up") -> dict[int, int]:
        out: dict[int, int] = {}
        for r in self.records:
            if r.direction == direction:
                out[r.rnd] = out.get(r.rnd, 0) + 1
        return out

    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.records)

    def mean_bytes_per_client_round(self) -> float:
        ups = [r for r in self.records if r.direction == "up"]
        if not ups:
            return 0.0
        rounds = len({r.rnd for r in ups})
        clients = max(len({r.cid for r in ups}), 1)
        return sum(r.nbytes for r in ups) / max(rounds, 1) / clients
