"""Gemma-2B: dense, MQA (kv=1), GeGLU, head_dim=256, 256k vocab.

[arXiv:2403.08295]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    kv_heads=1,
    d_ff=16384,
    vocab=256000,
    head_dim=256,
    act="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295 (Gemma)",
))
