"""Llama-3.2-Vision-90B: dense decoder with gated cross-attention image
layers every 5th layer.

[hf:meta-llama/Llama-3.2-11B-Vision (90B scale-up)] — the ViT vision
encoder + projector is the stubbed modality frontend; input_specs provides
projected patch embeddings [B, n_patches, d_model].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    act="swiglu",
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_patches=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B)",
))
