"""xLSTM-125M: alternating sLSTM + mLSTM blocks, no separate FFN.

[arXiv:2405.04517] — 12 blocks, d_model=768, 4 heads.  d_ff=0 per the
assignment (xLSTM blocks carry their own up/down projections).
"""
from repro.configs.base import ModelConfig, XLSTMConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, chunk=64),
    dtype="bfloat16",
    source="arXiv:2405.04517 (xLSTM)",
))
