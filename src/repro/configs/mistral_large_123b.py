"""Mistral-Large-2 (123B dense, GQA kv=8).

[hf:mistralai/Mistral-Large-Instruct-2407].  sliding_window=4096 is a
*variant we enable* (Mistral-7B lineage uses SWA-4096) so that the dense
arch qualifies for the long_500k sub-quadratic decode shape; recorded in
DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    kv_heads=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    act="swiglu",
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407 (+SWA variant)",
))
