"""Granite-3.0-2B: dense, GQA kv=8.

[hf:ibm-granite/granite-3.0-2b-base] — note vocab 49155 is not a multiple
of 256; logits are padded to vocab_padded for `model`-axis sharding.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    kv_heads=8,
    d_ff=8192,
    vocab=49155,
    act="swiglu",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
))
