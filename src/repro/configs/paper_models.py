"""The paper's own experiment models (§5.2): a CIFAR-10-scale CNN, a
Shakespeare-scale character LM, and a MedMNIST-scale classifier.

These are what Tables 2-4 are produced with; they are registered here so
the FL framework treats them as first-class architectures alongside the
assigned large archs.
"""
from repro.configs.base import ModelConfig, register

# Character-level LM used for the Shakespeare (LEAF) task.
CHARLM = register(ModelConfig(
    name="paper-charlm",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    kv_heads=4,
    d_ff=1024,
    vocab=128,
    act="gelu",
    dtype="float32",
    source="paper §5.2 (Shakespeare/LEAF char-LM)",
))
