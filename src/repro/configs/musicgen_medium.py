"""MusicGen-medium: decoder-only LM over EnCodec tokens (4 codebooks).

[arXiv:2306.05284] — the EnCodec audio codec (conv encoder/decoder) is the
stubbed modality frontend; this model consumes/predicts the 4 parallel
codebook token streams (vocab 2048 each) with summed codebook embeddings
and 4 parallel LM heads, as in the paper's "delay" interleaving.
MHA (kv_heads == n_heads == 24).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    kv_heads=24,
    d_ff=6144,
    vocab=2048,
    act="gelu",
    n_codebooks=4,
    source="arXiv:2306.05284 (MusicGen)",
))
