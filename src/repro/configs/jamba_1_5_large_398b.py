"""Jamba-1.5-Large: hybrid Mamba+attention (1:7) with MoE (16e top-2).

[arXiv:2403.19887] — 398B total params.  Attention layer every 8th layer
(the other 7 are Mamba blocks); MoE replaces the FFN every 2nd layer.
"""
from repro.configs.base import MambaConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    act="swiglu",
    attn_every=8,                 # 1 attention : 7 mamba
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576, every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    source="arXiv:2403.19887 (Jamba-1.5)",
))
