"""Kimi K2: trillion-parameter MoE, 384 experts top-8, 32B active.

[arXiv:2501.kimi2 (paper-table)] — 61L, d_model=7168, per-expert FFN 2048.
"""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    act="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048, every=1),
    source="arXiv:2501.kimi2 (Kimi K2, paper-table)",
))
