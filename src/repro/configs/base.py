"""Architecture configuration system.

Every assigned architecture is expressed as a :class:`ModelConfig`; the model
zoo (``repro.models``) consumes these declaratively.  Each config file under
``repro/configs/`` exports a ``CONFIG`` object and cites its source in
``source``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # hidden dim of each expert FFN
    every: int = 1                # MoE replaces the FFN every Nth layer
    capacity_factor: float = 1.25
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    chunk: int = 128              # chunked selective-scan chunk length


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 2          # sLSTM block every Nth block; rest mLSTM
    proj_factor: float = 2.0      # mLSTM up-projection factor
    chunk: int = 64               # mLSTM chunkwise-parallel chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int                     # dense FFN hidden (for MoE archs: see moe.d_expert)
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "swiglu"           # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    sliding_window: int = 0       # 0 -> full causal attention
    attn_every: int = 0           # hybrid: attention layer every Nth layer (rest Mamba)
    cross_attn_every: int = 0     # vlm: cross-attention layer every Nth layer
    n_patches: int = 576          # vlm stub: number of image patch embeddings
    n_codebooks: int = 0          # audio: EnCodec codebooks (parallel heads)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded up to a multiple of 256 so logits shard over `model`."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def is_decoder(self) -> bool:
        return True  # all assigned archs are decoder LMs

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode path exists (SSM/hybrid state or sliding window)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.xlstm is not None
            or self.mamba is not None and self.attn_every == 0
            or self.sliding_window > 0
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            seq_friendly: bool = True) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests.

    Keeps the structural features (GQA ratio, MoE, hybrid interleave,
    cross-attn, codebooks) while shrinking every dimension.
    """
    n_heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.kv_heads, n_heads))
    while n_heads % kv:
        kv -= 1
    head_dim = d_model // n_heads
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        kv_heads=kv,
        head_dim=head_dim,
        d_ff=2 * d_model if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        n_patches=16 if cfg.cross_attn_every else cfg.n_patches,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=4, top_k=min(2, cfg.moe.top_k), d_expert=2 * d_model,
            every=min(cfg.moe.every, n_layers),
            capacity_factor=2.0,  # tiny token counts need slack
            load_balance_coef=cfg.moe.load_balance_coef,
        )
    if cfg.mamba is not None:
        kw["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2, chunk=16)
    if cfg.xlstm is not None:
        kw["xlstm"] = XLSTMConfig(slstm_every=2, proj_factor=2.0, chunk=8)
    if cfg.attn_every:
        kw["attn_every"] = min(cfg.attn_every, n_layers)
    if cfg.cross_attn_every:
        kw["cross_attn_every"] = min(cfg.cross_attn_every, n_layers)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate registry lazily
    from repro import configs as _c  # noqa: F401  (triggers submodule imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
