"""Qwen3-MoE-235B-A22B: 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B (235B-A22B scale)] — 94L, d_model=4096,
per-expert FFN 1536.
"""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=64,
    act="swiglu",
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536, every=1),
    source="hf:Qwen/Qwen3-30B-A3B (235B-A22B)",
))
