"""Architecture + input-shape registry.

Each ``<arch>.py`` module registers one assigned architecture (with citation).
``INPUT_SHAPES`` defines the assigned workload shapes.
"""
from repro.configs.base import (  # noqa: F401
    MambaConfig, MoEConfig, ModelConfig, XLSTMConfig,
    get_config, list_archs, reduced, register,
)

# Input shapes assigned to this paper -----------------------------------------
from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

# Import side effects register every architecture.
from repro.configs import (  # noqa: F401, E402
    jamba_1_5_large_398b,
    xlstm_125m,
    mistral_large_123b,
    starcoder2_7b,
    gemma_2b,
    kimi_k2_1t_a32b,
    granite_3_2b,
    musicgen_medium,
    llama_3_2_vision_90b,
    qwen3_moe_235b_a22b,
    paper_models,
)

ASSIGNED_ARCHS = [
    "jamba-1.5-large-398b",
    "xlstm-125m",
    "mistral-large-123b",
    "starcoder2-7b",
    "gemma-2b",
    "kimi-k2-1t-a32b",
    "granite-3-2b",
    "musicgen-medium",
    "llama-3.2-vision-90b",
    "qwen3-moe-235b-a22b",
]
