"""StarCoder2-7B: dense, GQA kv=4, native sliding-window 4096, RoPE.

[arXiv:2402.19173]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    kv_heads=4,
    d_ff=18432,
    vocab=49152,
    head_dim=128,
    act="gelu",
    sliding_window=4096,
    rope_theta=100_000.0,
    source="arXiv:2402.19173 (StarCoder2)",
))
