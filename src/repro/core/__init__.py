"""The paper's primary contribution: the federated round step (Algorithm 1),
robust aggregation, and communication-efficient compression."""
from repro.core.round import FLConfig, build_fl_round_step, build_local_train  # noqa: F401
from repro.core.async_round import (AdaptiveStalenessController, AsyncConfig,  # noqa: F401
                                    build_buffer_commit_step,
                                    build_chunked_commit_steps,
                                    build_client_update_step,
                                    staleness_weights)
from repro.core.pipeline import UpdatePipeline, build_update_pipeline  # noqa: F401
from repro.core.compression import CompressionConfig, compress_tree, payload_bytes  # noqa: F401
from repro.core.secure_agg import masked_payload_bytes  # noqa: F401
from repro.core.convergence import ConvergenceMonitor  # noqa: F401
