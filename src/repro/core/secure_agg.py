"""Secure aggregation via pairwise additive masking (Bonawitz et al. 2017),
the paper's §6 "Secure aggregation" future-work item, implemented as an
optional layer over the round step.

Each participating client (i) adds, for every other participant (j), a
pseudorandom mask PRF(seed_ij) with sign sgn(j-i); all masks cancel in the
sum, so the orchestrator learns ONLY the aggregate — never an individual
update.  Dropout handling uses the standard seed-reveal: masks are only
applied between pairs of clients that both participate (simulated: the
jit'd round knows the final participation vector, standing in for the
reveal round).

This is a faithful *functional* implementation of the protocol algebra
(masking, cancellation, dropout unwinding).  The Diffie-Hellman key
agreement and Shamir secret sharing of the real protocol are outside an
offline container's scope; the symmetric seed matrix stands in for the
agreed keys.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def pairwise_seeds(round_seed: int, num_clients: int) -> np.ndarray:
    """[C, C] symmetric int32 seed matrix (seed_ij == seed_ji), host-side —
    stands in for per-pair DH-agreed keys."""
    rng = np.random.default_rng(round_seed)
    m = rng.integers(0, 2**31 - 1, (num_clients, num_clients), np.int64)
    sym = np.triu(m, 1)
    return (sym + sym.T).astype(np.int32)


def _pair_mask(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def mask_update(update_tree, client_idx: int, seeds, participation):
    """Add client `client_idx`'s pairwise masks.  participation: [C] 0/1 —
    masks are only exchanged between pairs that both participate."""
    C = seeds.shape[0]

    def mask_leaf(leaf):
        total = jnp.zeros(leaf.shape, jnp.float32)
        for j in range(C):
            if j == client_idx:
                continue
            m = _pair_mask(seeds[client_idx, j], leaf.shape)
            sign = 1.0 if client_idx < j else -1.0
            total = total + sign * m * participation[j]
        total = total * participation[client_idx]
        return (leaf.astype(jnp.float32) + total).astype(leaf.dtype)

    return jax.tree.map(mask_leaf, update_tree)


def aggregate_masked(masked_updates, participation):
    """Sum masked updates over the leading client dim: pairwise masks cancel
    among participants, recovering sum(participating updates) exactly."""
    def agg(d):
        p = participation.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
        return (d * p).sum(0)
    return jax.tree.map(agg, masked_updates)


def secure_weighted_mean(updates, weights, participation, seeds):
    """End-to-end: mask each client's (pre-weighted) update, aggregate, and
    normalise.  `updates` leaves have leading client dim C."""
    C = seeds.shape[0]

    def weighted(d):
        w = (weights * participation).reshape(
            (-1,) + (1,) * (d.ndim - 1)).astype(jnp.float32)
        return d.astype(jnp.float32) * w

    pre = jax.tree.map(weighted, updates)
    masked = [mask_update(jax.tree.map(lambda x: x[i], pre), i, seeds,
                          participation) for i in range(C)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *masked)
    total = aggregate_masked(stacked, participation)
    denom = jnp.maximum((weights * participation).sum(), 1e-12)
    return jax.tree.map(lambda t: t / denom, total)
