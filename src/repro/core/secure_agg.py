"""Secure aggregation via commit-keyed pairwise additive masking.

The protocol algebra is Bonawitz et al. 2017 (the paper's §6 privacy
layer): each participating update slot (i) adds, for every other
participating slot (j), a pseudorandom mask with sign sgn(id_j - id_i);
the masks cancel pairwise in the sum, so the aggregator only ever learns
the aggregate — never an individual update.

What changed vs. the original module (and why):

  * **Commit-keyed PRF, not a round-cohort seed matrix.**  Masks are
    ``PRF(commit_key, min(id_i, id_j), max(id_i, id_j))`` where the
    commit key is unique per server commit (``commit_key(commit_id)``,
    or any per-commit PRNGKey such as the commit step's rng).  A
    buffered-async server has no fixed round cohort — the participant
    set of a commit is whatever subset of the buffer survived timeouts
    and ``max_staleness`` drops — so the key must be bound to the commit
    and the pair identities, nothing else.  Two slots of the same pair
    always derive the same key regardless of slot order (min/max), which
    is what makes the masks cancel.
  * **Dropout / padding unwinding via the participation vector.**  A
    slot padded out by a timeout commit (mask 0) or a dropped client
    never participates: every pair mask touching it is multiplied by
    ``p_i * p_j`` and vanishes — the functional stand-in for the seed
    -reveal round of the real protocol (participants reveal the pair
    seeds of dropped peers so the server can subtract the orphaned
    masks).
  * **Vectorised masking.**  ``mask_update`` used to build its masks in
    an O(C^2) Python loop of per-pair ``jax.random.normal`` calls, which
    neither jits nor scales.  Mask generation is now a ``vmap`` over a
    folded-in key array (``_pair_keys``), so the whole masking stage is
    a single jit-compatible expression.

The Diffie-Hellman key agreement and Shamir sharing of the real protocol
are outside an offline container's scope; the keyed PRF stands in for
the agreed pair keys and the participation vector for the reveal round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MASK_DOMAIN_TAG = 0x5EC_A66   # domain separator: secure-agg mask keys
#                               (shared with core.pipeline's key derivation)


def commit_key(commit_id, base_seed: int = 0):
    """Per-commit PRF key: PRNGKey(base_seed) folded with the commit id.

    Any per-commit-unique PRNGKey works as a commit key (the pipeline
    derives one from the commit step's rng); this helper is the explicit
    (commit_id -> key) form used by tests and documentation."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(base_seed), MASK_DOMAIN_TAG),
        commit_id)


def pair_mask(key, id_i, id_j, shape):
    """The symmetric pair mask PRF(key, i, j); sign is applied by callers
    (sgn(id_j - id_i) on slot i's side)."""
    lo = jnp.minimum(id_i, id_j)
    hi = jnp.maximum(id_i, id_j)
    k = jax.random.fold_in(jax.random.fold_in(key, lo), hi)
    return jax.random.normal(k, shape, jnp.float32)


def pair_seeds(key, ids):
    """Symmetric [K, K] uint32 pair seeds PRF(key, lo, hi) for the
    integer-domain masking kernel (kernels/fused_quant_mask): both slots
    of a pair derive the SAME seed (min/max id ordering), so both draw
    identical mask words and the signed combination cancels exactly under
    uint32 wraparound.  Same (key, lo, hi) keying as the float-domain
    ``pair_mask`` — only the PRF output domain differs (one uint32 seed
    that the kernel's avalanche hash streams over element indices, rather
    than a normal draw per element)."""
    lo = jnp.minimum(ids[:, None], ids[None, :]).reshape(-1)
    hi = jnp.maximum(ids[:, None], ids[None, :]).reshape(-1)
    flat = jax.vmap(lambda l, h: jax.random.bits(
        jax.random.fold_in(jax.random.fold_in(key, l), h),
        dtype=jnp.uint32))(lo, hi)
    K = ids.shape[0]
    return flat.reshape(K, K)


def pair_coef_int(ids, participation):
    """Integer {-1, 0, +1} variant of ``_pair_coef`` for the quantized
    masking domain: sgn(id_j - id_i) * [p_i > 0] * [p_j > 0] as int32,
    applied to mask words as exact two's-complement multiplies.  The sign
    comes from comparisons, not subtraction — unsigned id dtypes would
    wrap the difference and break the antisymmetry masks cancel by."""
    sign = ((ids[None, :] > ids[:, None]).astype(jnp.int32)
            - (ids[None, :] < ids[:, None]).astype(jnp.int32))
    p = (participation > 0).astype(jnp.int32)
    return sign * p[None, :] * p[:, None]


def _pair_coef(ids, participation):
    """[K, K] signed pair coefficients sgn(id_j - id_i) * p_i * p_j.

    Zero on the diagonal (sgn 0), zero for any pair touching a
    non-participant — the dropout/padding unwinding.

    NOTE: participating ids must be UNIQUE within a commit.  Two slots
    sharing an id would both derive the SAME pair key toward any third
    participant and add its mask twice against one subtraction — the
    masks would NOT cancel.  Callers therefore key on per-commit slot
    indices (a client contributing two buffered updates to one commit
    occupies two distinct slots), never on raw client ids."""
    sign = jnp.sign(ids[None, :] - ids[:, None]).astype(jnp.float32)
    return sign * participation[None, :] * participation[:, None]


def _row_total(key, ids, coef_row, id_i, shape):
    """Slot i's summed pair masks: K PRF draws (vmapped), one einsum."""
    lo = jnp.minimum(ids, id_i)
    hi = jnp.maximum(ids, id_i)
    keys = jax.vmap(
        lambda l, h: jax.random.fold_in(jax.random.fold_in(key, l), h))(lo, hi)
    pm = jax.vmap(lambda k: jax.random.normal(k, shape, jnp.float32))(keys)
    return jnp.einsum("j,j...->...", coef_row, pm)


def mask_slot(key, ids, participation, idx, tree):
    """Mask ONE slot's update (tree without a leading slot dim) — the
    streaming form used inside sequential scans.  O(K) pair draws, all
    vmapped."""
    coef = _pair_coef(ids, participation)[idx]          # [K]

    def mask_leaf(leaf):
        total = _row_total(key, ids, coef, ids[idx], leaf.shape)
        return (leaf.astype(jnp.float32) + total).astype(leaf.dtype)

    return jax.tree.map(mask_leaf, tree)


def mask_batch(tree, key, ids, participation):
    """Mask a full stacked batch (leaves [K, ...]): the pair-mask PRF is a
    vmapped fold_in over each slot's key row, streamed slot by slot with
    ``lax.map`` so peak memory stays O(K * leaf) — never the O(K^2 * leaf)
    of materialising the full pair grid — while remaining one
    jit-compatible expression (no Python loop over pairs)."""
    K = ids.shape[0]
    coef = _pair_coef(ids, participation)               # [K, K]

    def mask_leaf(leaf):
        shape = leaf.shape[1:]
        totals = jax.lax.map(
            lambda i: _row_total(key, ids, coef[i], ids[i], shape),
            jnp.arange(K))
        return (leaf.astype(jnp.float32) + totals).astype(leaf.dtype)

    return jax.tree.map(mask_leaf, tree)


def mask_update(update_tree, client_idx: int, key, ids, participation):
    """Add slot ``client_idx``'s pairwise masks to its (pre-weighted)
    update.  Vectorised replacement for the old per-pair Python loop —
    see ``mask_slot``."""
    return mask_slot(key, ids, participation, client_idx, update_tree)


def aggregate_masked(masked_updates, participation):
    """Sum masked updates over the leading slot dim: pairwise masks cancel
    among participants, recovering sum(participating updates) exactly."""
    def agg(d):
        p = participation.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
        return (d * p).sum(0)
    return jax.tree.map(agg, masked_updates)


def secure_weighted_mean(updates, weights, participation, key, ids=None):
    """End-to-end reference: pre-weight each slot's update, mask, sum,
    normalise by the (public) participating weight mass.  `updates`
    leaves have leading slot dim K."""
    K = jax.tree.leaves(updates)[0].shape[0]
    if ids is None:
        ids = jnp.arange(K, dtype=jnp.int32)

    def weighted(d):
        w = (weights * participation).reshape(
            (-1,) + (1,) * (d.ndim - 1)).astype(jnp.float32)
        return d.astype(jnp.float32) * w

    pre = jax.tree.map(weighted, updates)
    masked = mask_batch(pre, key, ids, participation)
    total = aggregate_masked(masked, participation)
    denom = jnp.maximum((weights * participation).sum(), 1e-12)
    return jax.tree.map(lambda t: t / denom, total)


def masked_payload_bytes(tree, cfg=None, n_slots: int = 2) -> int:
    """Wire bytes of one MASKED update slot.

    Without quantization, additive masks are dense f32 noise, so every
    leaf costs 4 bytes/element whatever the compression config says the
    plain path would have paid.  WITH quantization (``cfg.quantize_bits``)
    masking moves into the quantized integer domain
    (kernels/fused_quant_mask): each element ships as one finite-ring word
    of ``quantize_bits + ceil(log2(n_slots))`` bits — the headroom keeps
    the sum of ``n_slots`` bounded words faithful before wraparound — plus
    one f32 scale per block, which collapses the historical ~3.9x masked
    blowup (table_secure_agg.json) to roughly the quantized wire size.
    Sparsity still does not survive masking either way: masked words are
    uniformly dense."""
    bits = int(getattr(cfg, "quantize_bits", 0) or 0) if cfg is not None else 0
    if not bits:
        return int(sum(np.prod(l.shape) * 4 for l in jax.tree.leaves(tree)))
    ring_bits = bits + max(1, int(np.ceil(np.log2(max(n_slots, 2)))))
    block = int(getattr(cfg, "block", 256))
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(np.prod(leaf.shape))
        total += int(n * ring_bits / 8 + np.ceil(n / block) * 4)
    return total
