"""Communication-efficient update compression (paper §4.3).

Three techniques, applied to model-update pytrees before aggregation:
  * gradient quantization   — blockwise symmetric int8/int4 with per-block
                              scales (optionally stochastic rounding),
  * update sparsification   — per-block magnitude top-k,
  * federated dropout       — structured random neuron (output-column) masks.

All are *straight-through* inside the jit'd round step: compress(x) returns
the decompressed value the server would reconstruct, so the training math
sees exactly the information that crossed the wire, while
``payload_bytes()`` accounts for the bytes that transfer would need
(used for Table 4 / ablation reproductions).

Pure-jnp implementations live here; the Pallas TPU kernels
(repro.kernels.{quantize,topk_sparsify}) are drop-in replacements selected
with ``use_kernels=True`` and validated against these in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CompressionConfig:
    quantize_bits: int = 0        # 0 (off) | 8 | 4
    stochastic_rounding: bool = True
    topk_frac: float = 0.0        # fraction of entries KEPT per block (0 = off)
    dropout_frac: float = 0.0     # fraction of output neurons dropped (0 = off)
    block: int = 256              # quant/top-k block length
    use_kernels: bool = False     # use Pallas kernels for the hot loops
    use_fused: bool = True        # fuse the commit path (compress + mask +
    #                               accumulate in one pass, kernels/fused_*);
    #                               mesh-native (shard_mapped over an active
    #                               GSPMD mesh); ineligible configs (e.g.
    #                               stochastic rounding) still route those
    #                               stages to the bit-identical jnp oracle

    @property
    def enabled(self) -> bool:
        return bool(self.quantize_bits or self.topk_frac or self.dropout_frac)

    @property
    def topk_k(self) -> int:
        """Entries KEPT per block under topk_frac (0 = top-k off)."""
        if not self.topk_frac:
            return 0
        return max(1, int(np.ceil(self.topk_frac * self.block)))


# ---------------------------------------------------------------------------
# blockwise helpers
#
# Blocks are taken along the LAST dimension (padded to a block multiple),
# never by flattening the whole tensor: flattening a 2-D-sharded parameter is
# not layout-preserving, so under GSPMD it all-gathers the full tensor to
# every device — measured at 529 GB/client/round for mistral-large and
# ~7 TB for kimi-k2 before this change (EXPERIMENTS.md §Perf iteration 1).
# Last-dim blocking reshapes [..., F] -> [..., F/block, block], which splits
# the sharded dim onto the new major axis and stays completely local.
# ---------------------------------------------------------------------------

def _to_blocks(x, block):
    """[..., L] -> ([..., nb, block] float32, pad)."""
    L = x.shape[-1] if x.ndim else 1
    x = x.reshape(x.shape or (1,)).astype(jnp.float32)
    pad = (-L) % block
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    return x.reshape(*x.shape[:-1], (L + pad) // block, block), pad


def _from_blocks(blocks, pad, shape, dtype):
    y = blocks.reshape(*blocks.shape[:-2], -1)
    if pad:
        y = y[..., :-pad]
    return y.reshape(shape).astype(dtype)


def quantize_dequant(x, bits: int, block: int = 256, rng=None,
                     stochastic: bool = True, use_kernel: bool = False):
    """Blockwise symmetric quantization round-trip."""
    if use_kernel and not stochastic:
        from repro.kernels import ops as kops
        return kops.quantize_dequant(x, bits=bits, block=block)
    b, pad = _to_blocks(x.astype(jnp.float32), block)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(b), axis=-1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    y = b / scale
    if stochastic and rng is not None:
        y = jnp.floor(y + jax.random.uniform(rng, y.shape))
    else:
        y = jnp.round(y)
    y = jnp.clip(y, -qmax - 1, qmax) * scale
    return _from_blocks(y, pad, x.shape, x.dtype)


def topk_sparsify(x, frac: float, block: int = 256, use_kernel: bool = False):
    """Keep the top ceil(frac*block) entries by |magnitude| per block."""
    k = max(1, int(np.ceil(frac * block)))
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.topk_sparsify(x, k=k, block=block)
    b, pad = _to_blocks(x.astype(jnp.float32), block)
    mag = jnp.abs(b)
    # threshold semantics (same as the Pallas kernel): keep every entry with
    # |x| >= the k-th largest magnitude; ties all kept.
    thresh = -jnp.sort(-mag, axis=-1)[..., k - 1:k]
    y = jnp.where(mag >= thresh, b, 0.0)
    return _from_blocks(y, pad, x.shape, x.dtype)


def federated_dropout(x, frac: float, rng):
    """Drop a random `frac` of output neurons (last dim), rescale the rest."""
    if x.ndim < 2:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - frac, (x.shape[-1],))
    return jnp.where(keep, x / (1.0 - frac), 0.0).astype(x.dtype)


# ---------------------------------------------------------------------------
# tree-level API
# ---------------------------------------------------------------------------

def compress_tree(tree, cfg: CompressionConfig, rng):
    """Straight-through compression of an update pytree."""
    if not cfg.enabled:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for leaf, r in zip(leaves, rngs):
        y = leaf
        r1, r2 = jax.random.split(r)
        if cfg.dropout_frac:
            y = federated_dropout(y, cfg.dropout_frac, r1)
        if cfg.topk_frac:
            y = topk_sparsify(y, cfg.topk_frac, cfg.block,
                              use_kernel=cfg.use_kernels)
        if cfg.quantize_bits:
            y = quantize_dequant(y, cfg.quantize_bits, cfg.block, rng=r2,
                                 stochastic=cfg.stochastic_rounding,
                                 use_kernel=cfg.use_kernels)
        out.append(y.astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def payload_bytes(tree, cfg: Optional[CompressionConfig]) -> int:
    """Bytes one client's update costs on the wire under `cfg`.

    Uncompressed: dtype bytes per element.  Quantized: bits/8 per element +
    one f32 scale per block.  Top-k: only k entries (+4-byte indices) per
    block survive.  Dropout removes a frac of columns entirely.
    """
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(np.prod(leaf.shape))
        if cfg is None or not cfg.enabled:
            total += n * jnp.dtype(leaf.dtype).itemsize
            continue
        frac_cols = 1.0 - (cfg.dropout_frac if leaf.ndim >= 2 else 0.0)
        n_eff = n * frac_cols
        if cfg.topk_frac:
            k = max(1, int(np.ceil(cfg.topk_frac * cfg.block)))
            per_entry_bits = (cfg.quantize_bits or
                              jnp.dtype(leaf.dtype).itemsize * 8) + 32  # + index
            n_blocks = np.ceil(n_eff / cfg.block)
            total += int(n_blocks * k * per_entry_bits / 8 + n_blocks * 4)
        elif cfg.quantize_bits:
            n_blocks = np.ceil(n_eff / cfg.block)
            total += int(n_eff * cfg.quantize_bits / 8 + n_blocks * 4)
        else:
            total += int(n_eff * jnp.dtype(leaf.dtype).itemsize)
    return total
