"""Composable update pipeline: ONE stage stack for every execution regime.

Before this module, the compress -> weight -> aggregate transform was
re-implemented four times (round.py parallel / sequential /
pod_sequential, async_round.py buffered commit), so every cross-cutting
feature — compression tweaks, secure aggregation, staleness discounting
— had to be patched in four places.  ``build_update_pipeline(cfg)``
builds the stack once from ``FLConfig`` and all four regimes close over
it.

Stage contract
--------------
Stages are pure, jit-compatible functions over update pytrees plus
per-slot scalars.  A "slot" is one client update in a batch of K (a sync
cohort or an async commit buffer).  The canonical order is

    compress -> weight/discount -> secure_mask -> aggregate -> normalise

  * ``compress(tree, rng)``            straight-through compression of
    what crosses the wire (repro.core.compression); per-slot rngs come
    from ``jax.random.split(rng, K)`` so batched and streaming callers
    draw identical randomness.
  * ``client_weights(...) -> (w_eff, w_raw)``  combines data-size
    weights, the participation mask, losses (aggregation='weighted') and
    — async only — the staleness discount ``1/(1+s)^a``.  ``w_raw`` is
    the UN-discounted mass; dividing by it (not by ``w_eff``) is what
    makes a uniformly stale buffer take a proportionally smaller server
    step (FedBuff) instead of having the discount cancel in the mean.
  * ``secure_mask``                    adds commit-keyed pairwise masks
    (core.secure_agg) to the PRE-WEIGHTED slot updates.  Masking must
    follow weighting: the server sums ``w_i * d_i + m_i`` and the
    ``m_i`` cancel only if they are not scaled per-slot afterwards.
    (The ISSUE's "compress -> secure_mask -> weight" stage list names
    the stages; the algebra fixes this order.)
  * ``aggregate``                      weighted sum over the slot dim
    (or a plain sum of pre-weighted masked slots).
  * ``normalise``                      divide by the raw weight mass.

Execution-mode mapping:
  * parallel / async commit — ``combine`` consumes the full [K, ...]
    stack (trimmed-mean and hierarchical pod variants included).
  * sequential — the scan builds per-slot contributions with
    ``contribution`` and folds them with ``accum_add``; ``normalise``
    closes the stream.  Identical math, streaming memory.
  * pod_sequential / hierarchical — per-pod partial sums are compressed
    (``compress``) and combined across pods with ``combine_pods``.

Commit-keyed masking scheme (cfg.secure_agg)
--------------------------------------------
Masks are ``PRF(commit_key, min(id_i, id_j), max(id_i, id_j))`` with
sign ``sgn(id_j - id_i)`` on slot i's side — symmetric in the pair, so
they cancel in the sum.  The commit key is derived (``fold_in``) from
the per-commit rng, which is unique per commit and checkpointed, so
kill/resume reproduces the exact masks.  Participant ids are UNIQUE
per-commit slot indices (arange over the cohort/buffer/pods — a fast
client landing two buffered updates in one async commit occupies two
slots, i.e. two logical participants; duplicate ids would make a pair
key collide and its mask survive the sum uncancelled).  Slots padded
out by timeout commits, dropped clients, or ``max_staleness`` drops
carry participation 0: every pair mask touching them is zeroed — the
functional stand-in for the protocol's seed-reveal unwinding.  The
server therefore only ever sees masked per-slot updates whose masks
cancel within each commit; masked-vs-plain aggregates agree to float32
cancellation error (<= 1e-5, pinned in tests/test_secure_pipeline.py).

Fused commit path (compression.use_fused, default on)
-----------------------------------------------------
Every stage between compress and normalise is elementwise or a slot
reduction — pure HBM bandwidth — so the batched combinators fuse the
whole ``compress -> weight/discount -> (mask) -> aggregate`` stack into
single-pass Pallas kernels (kernels/fused_quant_mask, kernels/
fused_accum): each slot leaf is read once and the reduced leaf written
once, instead of a full [K, ...] intermediate materialized per stage.
Which boundaries fuse:

  * plain commits, deterministic quantize and/or top-k
    -> one kernel (top-k + per-slot-block quantize + discounted sum).
  * plain commits, no compression -> the fused accumulate kernel
    (discount computed in-kernel from raw weights + staleness).
  * secure commits WITH quantization -> the integer-domain kernel; see
    below.  Secure WITHOUT quantization keeps the float-domain masks.
  * stochastic rounding / federated dropout need per-slot randomness, so
    those stages stay unfused (per-slot jnp or per-slot Pallas compress)
    and only the accumulate fuses.
  * streaming (sequential scan) and pod-local compress stages route
    per-slot work through the Pallas compress kernels
    (``use_kernels``) — there is no slot batch to fuse across.

Fusion is mesh-native: under an active GSPMD mesh the kernels/ops entry
points wrap every Pallas call in shard_map over the mesh's multi-device
axes (row-sharding the blocked commit stack; see kernels/ops.py), so
``UpdatePipeline.fused`` stays on when a mesh is active.  The fused
combinators also BUCKET the tree: all leaves of the slot-stacked update
tree are concatenated into one blocked [K, rows, block] bucket per
commit, so a 100+-leaf model costs O(1) kernel launches instead of one
per leaf shape (kops.fused_*_tree).  ``allow_fused=False`` remains the
explicit caller escape hatch, and stochastic rounding still routes to
the bit-identical jnp oracle.

Why masking moves to the integer domain under quantization: float-domain
pairwise masks are dense f32 noise, so a masked wire slot costs 4
bytes/element no matter how hard the plain payload was compressed
(the historical ~3.9x blowup in table_secure_agg.json).  Standard SecAgg
instead masks the quantized WIRE words with modular arithmetic in a
finite ring.  When ``secure_agg`` and ``quantize_bits`` are both set the
commit therefore (1) quantizes every slot's weighted values onto ONE
commit-common per-block grid (masks can only cancel if all slots share a
grid), (2) adds uint32 modular pairwise mask words to the int32 wire
words, and (3) sums — the masks cancel EXACTLY (integer wraparound, no
float cancellation error) and the sum dequantizes through the common
scale.  The wire then ships ring words of
``quantize_bits + ceil(log2(K))`` bits (secure_agg.masked_payload_bytes)
instead of dense f32.  This is a SCHEME property, engaged whether or not
the Pallas kernel runs: ``use_fused`` only picks the executor (kernel vs
the bit-identical jnp oracle in kernels/ref.py), so fused and unfused
paths agree and kill/resume replay is executor-independent.  The
streaming (sequential) and cross-pod secure paths keep float-domain
masks: a scan sees one slot at a time and pods quantize on per-pod
grids, so neither can share a commit-common grid.

Build-time rejections: ``secure_agg`` + ``trimmed_mean`` (coordinate
-wise trimming needs individual updates, which masking is designed to
hide).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg
from repro.core import secure_agg as sec
from repro.core.compression import compress_tree
from repro.core.secure_agg import MASK_DOMAIN_TAG
from repro.kernels import ops as kops

if TYPE_CHECKING:                       # avoid circular import with round.py
    from repro.core.round import FLConfig


def staleness_weights(staleness, exponent):
    """The FedBuff polynomial discount ``1 / (1 + s)^a``.

    ``staleness`` counts server commits between a client's dispatch and
    its update's arrival; works on jnp or np arrays (used as its own
    NumPy reference in tests).  ``exponent`` may be a traced scalar —
    the adaptive-alpha path feeds the controller's current value per
    commit."""
    return (1.0 + staleness) ** (-exponent)


class UpdatePipeline:
    """The configured stage stack.  Stateless; every method is pure and
    jit-compatible, so one instance serves vmapped, scanned and batched
    callers alike."""

    def __init__(self, cfg: "FLConfig", n_pods: int = 1,
                 allow_fused: bool = True):
        if cfg.secure_agg and cfg.aggregation == "trimmed_mean":
            raise ValueError(
                "secure_agg is incompatible with aggregation='trimmed_mean': "
                "coordinate-wise trimming needs the individual updates that "
                "pairwise masking hides; use fedavg/weighted")
        comp = cfg.compression
        # Fusion survives an active mesh: kernels/ops wraps each Pallas call
        # in shard_map over the mesh (rows of the blocked commit stack are
        # sharded, the slot sum is shard-local), so the only off-switches
        # left are the config knob and the caller's explicit escape hatch.
        self.fused = (bool(getattr(comp, "use_fused", True))
                      and allow_fused)
        # fully-fusable compression: deterministic rounding, no per-slot
        # dropout randomness
        self._fusable_comp = (not comp.dropout_frac
                              and not (comp.quantize_bits
                                       and comp.stochastic_rounding))
        if self.fused and comp.enabled and not comp.use_kernels:
            # per-slot compress stages (sequential scan, pod-local compress)
            # route through the Pallas compress kernels under fusion
            cfg = dataclasses.replace(
                cfg, compression=dataclasses.replace(comp, use_kernels=True))
        self.cfg = cfg
        self.n_pods = n_pods

    # ------------------------------------------------------------- stage 1
    def compress(self, tree, rng):
        return compress_tree(tree, self.cfg.compression, rng)

    def compress_each(self, stacked, rng):
        """vmap the compress stage over the leading slot dim."""
        K = jax.tree.leaves(stacked)[0].shape[0]
        rngs = jax.random.split(rng, K)
        return jax.vmap(self.compress)(stacked, rngs)

    # ------------------------------------------------------------- stage 2
    def client_weights(self, weights, mask, losses=None, staleness=None,
                      exponent=None):
        """(w_eff, w_raw): discounted and raw per-slot weight vectors."""
        w_raw = agg.effective_weights(weights, mask, losses,
                                      self.cfg.aggregation)
        if staleness is None:
            return w_raw, w_raw
        w_eff = w_raw * staleness_weights(staleness.astype(jnp.float32),
                                          exponent)
        return w_eff, w_raw

    def client_weight(self, w_c, m_c, loss_c):
        """Scalar form for streaming (scan) callers."""
        return agg.effective_weights(w_c[None], m_c[None], loss_c[None],
                                     self.cfg.aggregation)[0]

    # ------------------------------------------------------------- stage 3
    def mask_key(self, rng):
        """Commit key for this aggregation's pairwise masks.  rng is the
        per-commit step rng — unique per commit and checkpointed, so it
        stands in for fold_in(base, commit_id) with identical algebra."""
        return jax.random.fold_in(rng, MASK_DOMAIN_TAG)

    def secure_mask(self, weighted_stack, key, ids, participation):
        return sec.mask_batch(weighted_stack, key, ids, participation)

    # --------------------------------------------------------- stages 4/5
    def weighted_sum(self, stacked, w):
        """sum_i w_i * d_i over the slot dim, in float32."""
        def one(d):
            wb = w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(jnp.float32)
            return (d.astype(jnp.float32) * wb).sum(0)
        return jax.tree.map(one, stacked)

    def normalise(self, summed, w_raw_sum):
        denom = jnp.maximum(w_raw_sum, 1e-12)
        return jax.tree.map(lambda s: (s / denom.astype(s.dtype)), summed)

    # ----------------------------------------------------------- streaming
    def accum_init(self, params_like):
        dt = jnp.dtype(self.cfg.accum_dtype)
        return jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params_like)

    def contribution(self, delta, wt, rng, idx=None, ids=None,
                     participation=None, key=None):
        """One slot's contribution to the running sum: compress ->
        weight -> (secure-mask).  The masked value is what "crosses the
        wire" to the server accumulator; masks cancel once every
        participant's contribution has been folded in.  The weighting
        product is carried in ``accum_dtype`` so streaming accumulation
        keeps the precision that knob asks for."""
        dt = jnp.dtype(self.cfg.accum_dtype)
        d = self.compress(delta, rng)
        pre = jax.tree.map(lambda x: wt.astype(dt) * x.astype(dt), d)
        if self.cfg.secure_agg:
            pre = sec.mask_slot(key, ids, participation, idx, pre)
        return pre

    def accum_add(self, acc, contrib):
        return jax.tree.map(lambda a, c: a + c.astype(a.dtype), acc, contrib)

    # --------------------------------------------------------- combinators
    def combine_unnormalised(self, deltas, weights, mask, losses, rng,
                             ids=None, staleness=None, exponent=None):
        """compress -> weight/discount -> (secure_mask) -> weighted sum,
        WITHOUT the closing normalise.  Returns (summed, w_eff, w_raw).

        Every stage up to normalise is slot-local or additive, so a commit
        over K slots equals the sum of this over any partition of the slots
        into chunks, normalised once by the total raw mass — the algebra the
        chunked async commit (AsyncConfig.commit_chunk) accumulates on.
        Each chunk must carry its own rng (fold_in per chunk): masks then
        cancel within each chunk independently, and per-slot compression
        randomness stays unique."""
        if self.cfg.aggregation == "trimmed_mean":
            raise ValueError(
                "trimmed_mean is not a chunk-accumulable aggregate: "
                "coordinate-wise trimming needs all slots at once")
        w_eff, w_raw = self.client_weights(weights, mask, losses,
                                           staleness, exponent)
        comp = self.cfg.compression
        if self.cfg.secure_agg:
            if ids is None:
                ids = jnp.arange(mask.shape[0], dtype=jnp.int32)
            if comp.quantize_bits:
                summed = self._fused_secure(deltas, w_eff, mask, rng, ids)
            else:
                stacked = self.compress_each(deltas, rng) \
                    if comp.enabled else deltas
                pre = jax.tree.map(
                    lambda d: d.astype(jnp.float32) * w_eff.reshape(
                        (-1,) + (1,) * (d.ndim - 1)), stacked)
                masked = self.secure_mask(pre, self.mask_key(rng), ids, mask)
                summed = jax.tree.map(lambda m: m.astype(jnp.float32).sum(0),
                                      masked)
        elif self.fused:
            s = (staleness.astype(jnp.float32) if staleness is not None
                 else jnp.zeros_like(w_raw))
            a = exponent if exponent is not None else 0.0
            if comp.enabled and self._fusable_comp:
                # one-pass: top-k + quantize + discount + sum, all leaves
                # bucketed into a single kernel launch
                leaves, treedef = jax.tree.flatten(deltas)
                summed = jax.tree.unflatten(
                    treedef, kops.fused_plain_commit_tree(
                        leaves, w_raw, s, a, bits=comp.quantize_bits,
                        k=comp.topk_k, block=comp.block))
            else:
                # per-slot stages that need slot randomness stay unfused;
                # the accumulate still fuses (one bucketed launch)
                stacked = (self.compress_each(deltas, rng)
                           if comp.enabled else deltas)
                leaves, treedef = jax.tree.flatten(stacked)
                summed = jax.tree.unflatten(
                    treedef, kops.fused_accum_tree(leaves, w_raw, s, a,
                                                   block=comp.block))
        else:
            stacked = self.compress_each(deltas, rng) \
                if comp.enabled else deltas
            summed = self.weighted_sum(stacked, w_eff)
        return summed, w_eff, w_raw

    def _fused_secure(self, deltas, w_eff, participation, rng, ids):
        """Integer-domain SecAgg commit (secure_agg + quantize_bits):
        weighted slot values quantize onto a commit-common per-block grid,
        int32 wire words pick up uint32 modular pairwise masks, masks
        cancel EXACTLY in the sum.  The scheme runs whether or not fusion
        is active — ``self.fused`` only picks the Pallas kernel over the
        bit-identical jnp oracle — so wire accounting, checkpoint replay
        and fused-vs-unfused parity are executor-independent."""
        comp = self.cfg.compression
        key = self.mask_key(rng)
        seeds = sec.pair_seeds(key, ids)
        coef = sec.pair_coef_int(ids, participation)
        stacked, k_in = deltas, comp.topk_k
        if comp.dropout_frac:
            # dropout draws per-slot randomness and must precede top-k, so
            # both run as per-slot pre-stages (quantize stays in the
            # integer-domain masked commit)
            pre = dataclasses.replace(comp, quantize_bits=0)
            rngs = jax.random.split(rng, ids.shape[0])
            stacked = jax.vmap(
                lambda t, r: compress_tree(t, pre, r))(stacked, rngs)
            k_in = 0
        leaves, treedef = jax.tree.flatten(stacked)
        # one bucketed launch for the whole tree; the bucket's row-major
        # element index reproduces the old per-leaf base accumulation, so
        # the mask stream is bitwise-unchanged
        nr = rng if comp.stochastic_rounding else None
        out = kops.fused_secure_commit_tree(
            leaves, w_eff, seeds, coef, bits=comp.quantize_bits, k=k_in,
            block=comp.block, use_pallas=self.fused, noise_rng=nr)
        return jax.tree.unflatten(treedef, out)

    def combine(self, deltas, weights, mask, losses, rng, ids=None,
                staleness=None, exponent=None):
        """The full batched stack over [K, ...] slot deltas.

        Returns (delta, w_eff, w_raw).  Serves the parallel sync mode
        (staleness=None) and the async buffered commit (staleness +
        exponent set); handles the trimmed-mean and hierarchical pod
        variants so no execution mode re-implements them."""
        if self.cfg.aggregation == "trimmed_mean":
            # robust trimming consumes RAW per-slot deltas (no compression,
            # no masking — rejected at build time): same as the historic
            # inline path
            w_eff, w_raw = self.client_weights(weights, mask, losses,
                                               staleness, exponent)
            return agg.trimmed_mean(deltas, mask), w_eff, w_raw
        if self.cfg.hierarchical and self.n_pods > 1:
            w_eff, w_raw = self.client_weights(weights, mask, losses,
                                               staleness, exponent)
            delta = self._combine_hierarchical(deltas, w_eff, w_raw, rng)
            return delta, w_eff, w_raw
        summed, w_eff, w_raw = self.combine_unnormalised(
            deltas, weights, mask, losses, rng, ids=ids,
            staleness=staleness, exponent=exponent)
        return self.normalise(summed, w_raw.sum()), w_eff, w_raw

    def _combine_hierarchical(self, deltas, w_eff, w_raw, rng):
        """Pod-local weighted sums -> compress -> cross-pod combine: only
        the compressed pod sums cross the slow cross-pod link."""
        P = self.n_pods
        K = w_eff.shape[0]
        per_pod = K // P

        def pod_sums(d):
            wb = w_eff.reshape(P, per_pod)
            dp = d.reshape((P, per_pod) + d.shape[1:])
            return (dp * wb.reshape(wb.shape + (1,) * (d.ndim - 1)
                                    ).astype(d.dtype)).sum(1)

        sums = jax.tree.map(pod_sums, deltas)          # [P, ...] un-normalised
        return self.combine_pods(sums, w_raw.sum(), rng)

    def combine_pods(self, pod_sums, w_total, rng, compressed=False):
        """Cross-pod tail of the stack: compress each pod's partial sum,
        secure-mask BETWEEN PODS (privacy at site granularity — each
        pod's aggregate is hidden from the others and the server), sum,
        normalise by the total raw weight mass.

        ``compressed=True`` when the caller already ran the compress
        stage per pod — pod_sequential compresses INSIDE its
        spmd-annotated pod vmap so the quantize/top-k work stays
        pod-local under GSPMD instead of all-gathering each pod's
        partial sum (see build_fl_round_step's client_spmd_axes note)."""
        P = jax.tree.leaves(pod_sums)[0].shape[0]
        sums = pod_sums if compressed else self.compress_each(pod_sums, rng)
        if self.cfg.secure_agg:
            # cross-pod masking stays float-domain even under quantization:
            # pod partial sums were quantized on per-pod grids, so there is
            # no common grid for integer masks to cancel on (and P is tiny
            # — the dense-mask bytes here are not the wire bottleneck)
            ones = jnp.ones((P,), jnp.float32)
            sums = self.secure_mask(sums, self.mask_key(rng),
                                    jnp.arange(P, dtype=jnp.int32), ones)
            summed = jax.tree.map(lambda s: s.astype(jnp.float32).sum(0),
                                  sums)
        elif self.fused:
            ones = jnp.ones((P,), jnp.float32)
            zeros = jnp.zeros((P,), jnp.float32)
            leaves, treedef = jax.tree.flatten(sums)
            summed = jax.tree.unflatten(
                treedef, kops.fused_accum_tree(
                    leaves, ones, zeros, 0.0,
                    block=self.cfg.compression.block))
        else:
            summed = jax.tree.map(lambda s: s.astype(jnp.float32).sum(0),
                                  sums)
        return self.normalise(summed, w_total)


def build_update_pipeline(cfg: "FLConfig", n_pods: int = 1,
                          allow_fused: bool = True) -> UpdatePipeline:
    """Build the stage stack once from FLConfig; all execution modes of
    round.py and async_round.py close over the returned pipeline.
    ``allow_fused=False`` forces the unfused stages — the explicit caller
    escape hatch.  An active mesh no longer disables fusion: the kernel
    entry points shard_map themselves over it (kernels/ops.py)."""
    return UpdatePipeline(cfg, n_pods=n_pods, allow_fused=allow_fused)
