"""Staleness-aware asynchronous (FedBuff-style) server aggregation.

DESIGN
------
The synchronous round step (repro.core.round) stacks C client batches,
trains every client against the SAME global params, and applies one
aggregate per round — a barrier: the round is as slow as its slowest
participant.  This module is the other half of the paper's heterogeneity
story: clients train against whatever params snapshot they were handed,
their deltas land in a bounded server buffer whenever they finish, and the
server commits an aggregate every K arrivals (or T seconds of quiet).  An
update that was computed ``s`` commits ago is *stale* — it is discounted,
not discarded, with the polynomial weight of FedBuff/FedAsync:

    w_eff[i] = effective_weights(weights, mask)[i] * 1 / (1 + s_i)^a

The committed delta is normalised by the UN-discounted weight mass
(``sum w_eff * d / sum w_raw``), so the discount shrinks the absolute
server step: a buffer in which every update is equally stale takes a
``1/(1+s)^a``-scaled step rather than a full one (the discount must not
cancel in the mean's denominator).

Split of responsibilities (mirrors round.py):
  * ``build_client_update_step``  — the jit'd per-client local-training
    step: ``(params_snapshot, batches[H, b, ...], rng) -> (delta, loss)``.
    Reuses ``build_local_train`` so FedProx / fused-kernel / sharding
    behaviour is identical to the sync path.
  * ``build_buffer_commit_step``  — the jit'd server step over a FIXED-K
    buffer: ``(params, server_state, deltas[K, ...], weights[K],
    staleness[K], mask[K], rng) -> (params', state', metrics)``.
    Timeout commits with fewer than K live updates pad with zero deltas
    and mask 0, so one compiled step serves every commit.  Compression is
    the same straight-through ``compress_tree`` pipeline as the sync
    round, applied per buffered update (what crosses the wire is the
    compressed delta).
  * Event ordering, buffer policy, staleness bookkeeping and comm
    accounting are HOST-side — repro.orchestrator.async_server.

Equivalence invariant (tested): with staleness forced to zero, a full
mask, and compression off, one buffer commit over the C deltas of a sync
round reproduces the sync round step's new params to <= 1e-5 — async is a
strict generalisation, not a different algorithm.

Limits encoded here rather than left to callers:
  * ``max_staleness`` — updates older than this are dropped by the
    orchestrator (weight would be ~0 anyway; dropping keeps the buffer
    from carrying dead weight).
  * accumulation/aggregation happens in float32 regardless of param
    dtype, like the sync path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg
from repro.core.compression import compress_tree
from repro.core.round import FLConfig, build_local_train, global_norm
from repro.optim import Optimizer, ServerOptimizer


@dataclass(frozen=True)
class AsyncConfig:
    """Policy knobs of the buffered-asynchronous execution regime."""
    buffer_size: int = 8            # K: commit every K buffered updates
    staleness_exponent: float = 0.5  # a in 1/(1+s)^a  (0 -> no discount)
    max_staleness: int = 20         # drop updates staler than this
    commit_timeout_s: float = 0.0   # T: commit a partial buffer once its
    #                                 oldest update has waited T sim-seconds
    #                                 without a K-commit (0 = off)
    max_concurrency: int = 16       # clients training at once

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.max_concurrency < 1:
            raise ValueError(
                f"max_concurrency must be >= 1, got {self.max_concurrency}")
        if self.max_staleness < 0 or self.staleness_exponent < 0 \
                or self.commit_timeout_s < 0:
            raise ValueError("max_staleness, staleness_exponent and "
                             "commit_timeout_s must be non-negative")


def staleness_weights(staleness, exponent: float):
    """The FedBuff polynomial discount ``1 / (1 + s)^a``.

    ``staleness`` counts server commits between a client's dispatch and its
    update's arrival; works on jnp or np arrays (used as its own NumPy
    reference in tests)."""
    return (1.0 + staleness) ** (-exponent)


def build_client_update_step(loss_fn: Callable, client_opt: Optimizer,
                             cfg: FLConfig, param_shardings=None):
    """jit-able ``(params_snapshot, batches[H, b, ...], rng) -> (delta, loss)``.

    Exactly the sync path's local training (same FedProx handling, same
    optimizer), run for ONE client against the params snapshot it was
    dispatched with."""
    return build_local_train(loss_fn, client_opt, cfg, param_shardings)


def build_buffer_commit_step(server_opt: ServerOptimizer, cfg: FLConfig,
                             async_cfg: AsyncConfig):
    """jit-able server commit over a fixed-size buffer of K client deltas.

    commit(params, server_state, deltas, weights, staleness, losses, mask,
           rng) -> (new_params, new_server_state, metrics)

    ``deltas`` leaves are [K, ...]; ``weights``/``staleness``/``losses``/
    ``mask`` are [K].  Padding slots carry mask 0 (their deltas never
    contribute).  ``losses`` feeds the "weighted" aggregation mode exactly
    as in the sync round; "trimmed_mean" is rejected at build time —
    coordinate-wise trimming over a staleness-discounted partial buffer has
    no agreed semantics yet (ROADMAP open item).
    """
    if cfg.aggregation == "trimmed_mean":
        raise ValueError(
            "aggregation='trimmed_mean' is not supported by the async "
            "buffered commit (robust trimming over a padded, "
            "staleness-weighted buffer is undefined); use fedavg/weighted "
            "or the sync round loop")
    K = async_cfg.buffer_size

    def commit(params, server_state, deltas, weights, staleness, losses,
               mask, rng):
        w_raw = agg.effective_weights(weights, mask, losses, cfg.aggregation)
        w = w_raw * staleness_weights(staleness.astype(jnp.float32),
                                      async_cfg.staleness_exponent)
        crng = jax.random.split(rng, K)
        deltas = jax.vmap(lambda d, r: compress_tree(d, cfg.compression, r))(
            deltas, crng)
        # normalise by the UN-discounted weight mass: a uniformly-stale
        # buffer must take a proportionally smaller server step (FedBuff),
        # not have its discount cancel out in the mean's denominator
        delta = agg.weighted_mean(deltas, w)
        shrink = (w.sum() / jnp.maximum(w_raw.sum(), 1e-12)).astype(jnp.float32)
        delta = jax.tree.map(lambda d: d * shrink.astype(d.dtype), delta)
        new_params, new_state = server_opt.apply(params, delta, server_state)
        metrics = {
            "delta_norm": global_norm(delta),
            "n_updates": mask.sum(),
            "mean_staleness": (staleness * mask).sum()
            / jnp.maximum(mask.sum(), 1),
            "effective_weight": w.sum(),
        }
        return new_params, new_state, metrics

    return commit
