"""Staleness-aware asynchronous (FedBuff-style) server aggregation.

DESIGN
------
The synchronous round step (repro.core.round) stacks C client batches,
trains every client against the SAME global params, and applies one
aggregate per round — a barrier: the round is as slow as its slowest
participant.  This module is the other half of the paper's heterogeneity
story: clients train against whatever params snapshot they were handed,
their deltas land in a bounded server buffer whenever they finish, and the
server commits an aggregate every K arrivals (or T seconds of quiet).  An
update that was computed ``s`` commits ago is *stale* — it is discounted,
not discarded, with the polynomial weight of FedBuff/FedAsync:

    w_eff[i] = effective_weights(weights, mask)[i] * 1 / (1 + s_i)^a

The committed delta is normalised by the UN-discounted weight mass
(``sum w_eff * d / sum w_raw``), so the discount shrinks the absolute
server step: a buffer in which every update is equally stale takes a
``1/(1+s)^a``-scaled step rather than a full one (the discount must not
cancel in the mean's denominator).

Split of responsibilities (mirrors round.py):
  * ``build_client_update_step``  — the jit'd per-client local-training
    step: ``(params_snapshot, batches[H, b, ...], rng) -> (delta, loss)``.
    Reuses ``build_local_train`` so FedProx / fused-kernel / sharding
    behaviour is identical to the sync path.
  * ``build_buffer_commit_step``  — the jit'd server step over a FIXED-K
    buffer: ``(params, server_state, deltas[K, ...], weights[K],
    staleness[K], losses[K], mask[K], ids[K], exponent, rng)
    -> (params', state', metrics)``.
    Timeout commits with fewer than K live updates pad with zero deltas
    and mask 0, so one compiled step serves every commit.  The whole
    compress -> weight/discount -> secure_mask -> aggregate -> normalise
    transform is the SAME ``repro.core.pipeline`` stage stack the three
    sync execution modes consume — there is no async-only aggregation
    math left here.  ``ids`` carries UNIQUE per-commit slot indices for
    commit-keyed pairwise masking under ``FLConfig.secure_agg`` (slot
    indices, not cids: a client with two updates in one buffer is two
    logical participants); ``exponent`` is the staleness discount's
    ``a``, a runtime scalar so the adaptive controller below can move it
    between commits without recompiling.
  * Event ordering, buffer policy, staleness bookkeeping and comm
    accounting are HOST-side — repro.orchestrator.async_server.

Equivalence invariant (tested): with staleness forced to zero, a full
mask, and compression off, one buffer commit over the C deltas of a sync
round reproduces the sync round step's new params to <= 1e-5 — async is a
strict generalisation, not a different algorithm.  The same holds with
``secure_agg`` on in both regimes (masks cancel within the commit).

Limits encoded here rather than left to callers:
  * ``max_staleness`` — updates older than this are dropped by the
    orchestrator (weight would be ~0 anyway; dropping keeps the buffer
    from carrying dead weight).
  * accumulation/aggregation happens in float32 regardless of param
    dtype, like the sync path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.pipeline import build_update_pipeline, staleness_weights  # noqa: F401  (re-export)
from repro.core.round import FLConfig, build_local_train, global_norm
from repro.optim import Optimizer, ServerOptimizer


@dataclass(frozen=True)
class AsyncConfig:
    """Policy knobs of the buffered-asynchronous execution regime."""
    buffer_size: int = 8            # K: commit every K buffered updates
    staleness_exponent: Union[float, str] = 0.5  # a in 1/(1+s)^a (0 -> no
    #                                 discount), or "adaptive": FedAsync-style
    #                                 online alpha from the observed staleness
    #                                 distribution (AdaptiveStalenessController)
    max_staleness: int = 20         # drop updates staler than this
    commit_timeout_s: float = 0.0   # T: commit a partial buffer once its
    #                                 oldest update has waited T sim-seconds
    #                                 without a K-commit (0 = off)
    max_concurrency: int = 16       # clients training at once
    commit_chunk: int = 0           # C: accumulate the buffer in C-sized
    #                                 chunks (one device call per chunk, one
    #                                 normalise+apply at the end) instead of
    #                                 stacking all K at once.  0 = off (the
    #                                 single-shot commit).  Chunked ==
    #                                 single-shot in exact arithmetic; float
    #                                 summation order differs, so the
    #                                 agreement is ~1e-5, not bitwise.

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.max_concurrency < 1:
            raise ValueError(
                f"max_concurrency must be >= 1, got {self.max_concurrency}")
        if self.commit_chunk < 0:
            raise ValueError(
                f"commit_chunk must be >= 0 (0 = single-shot commit), got "
                f"{self.commit_chunk}")
        if isinstance(self.staleness_exponent, str):
            if self.staleness_exponent != "adaptive":
                raise ValueError(
                    f"staleness_exponent must be a non-negative float or "
                    f"'adaptive', got {self.staleness_exponent!r}")
        elif self.staleness_exponent < 0:
            raise ValueError("staleness_exponent must be non-negative")
        if self.max_staleness < 0 or self.commit_timeout_s < 0:
            raise ValueError("max_staleness and commit_timeout_s must be "
                             "non-negative")

    @property
    def adaptive_staleness(self) -> bool:
        return self.staleness_exponent == "adaptive"

    def initial_exponent(self) -> float:
        return (AdaptiveStalenessController().alpha
                if self.adaptive_staleness else float(self.staleness_exponent))


class AdaptiveStalenessController:
    """Online FedAsync-style staleness exponent (host-side, deterministic).

    Rule: pick ``a`` so the polynomial discount at the OBSERVED tail
    staleness (EMA of the per-commit p90) equals ``w_floor``:

        a = ln(1/w_floor) / ln(1 + s_p90)

    A fleet whose updates arrive barely stale gets a sharp exponent (stale
    stragglers are outliers — discount them hard); a fleet where high
    staleness is the NORM gets a gentle one, so slow sites keep
    contributing instead of being starved (FedAsync's adaptive-alpha
    motivation).  A delta-norm drift brake tightens the discount whenever
    the committed step norm drifts above its EMA (divergence pressure —
    stale gradients amplifying the server step).

    The controller is pure host-side state: ``alpha`` is fed to the jit'd
    commit step as a runtime scalar, and ``state()``/``set_state()`` make
    it checkpointable so kill/--resume replays identical exponents.
    """

    def __init__(self, w_floor: float = 0.1, alpha0: float = 0.5,
                 alpha_min: float = 0.05, alpha_max: float = 4.0,
                 ema: float = 0.8, drift_gain: float = 1.0):
        self.w_floor = w_floor
        self.alpha = alpha0
        self.alpha_min, self.alpha_max = alpha_min, alpha_max
        self.ema = ema
        self.drift_gain = drift_gain
        self._stale_p90 = 0.0
        self._norm_ema = None

    def update(self, staleness, delta_norm: float) -> float:
        """Feed one commit's observed staleness values + committed delta
        norm; returns the alpha for the NEXT commit."""
        if len(staleness):
            p90 = float(np.quantile(np.asarray(staleness, np.float64), 0.9))
            self._stale_p90 = (self.ema * self._stale_p90
                               + (1.0 - self.ema) * p90)
        if self._stale_p90 > 0:
            base = np.log(1.0 / self.w_floor) / np.log1p(self._stale_p90)
        else:
            base = self.alpha_max     # nothing is stale: discount is inert
        drift = 0.0
        if delta_norm == delta_norm:  # skip NaN (empty commits)
            if self._norm_ema is None:
                self._norm_ema = float(delta_norm)
            else:
                drift = max(0.0, (float(delta_norm) - self._norm_ema)
                            / (self._norm_ema + 1e-12))
                self._norm_ema = (self.ema * self._norm_ema
                                  + (1.0 - self.ema) * float(delta_norm))
        self.alpha = float(np.clip(base * (1.0 + self.drift_gain * drift),
                                   self.alpha_min, self.alpha_max))
        return self.alpha

    def state(self) -> dict:
        return {"alpha": self.alpha, "stale_p90": self._stale_p90,
                "norm_ema": self._norm_ema}

    def set_state(self, s: dict):
        self.alpha = float(s["alpha"])
        self._stale_p90 = float(s["stale_p90"])
        self._norm_ema = (None if s["norm_ema"] is None
                          else float(s["norm_ema"]))


def build_client_update_step(loss_fn: Callable, client_opt: Optimizer,
                             cfg: FLConfig, param_shardings=None):
    """jit-able ``(params_snapshot, batches[H, b, ...], rng) -> (delta, loss)``.

    Exactly the sync path's local training (same FedProx handling, same
    optimizer), run for ONE client against the params snapshot it was
    dispatched with."""
    return build_local_train(loss_fn, client_opt, cfg, param_shardings)


def build_buffer_commit_step(server_opt: ServerOptimizer, cfg: FLConfig,
                             async_cfg: AsyncConfig):
    """jit-able server commit over a fixed-size buffer of K client deltas.

    commit(params, server_state, deltas, weights, staleness, losses, mask,
           ids, exponent, rng) -> (new_params, new_server_state, metrics)

    ``deltas`` leaves are [K, ...]; ``weights``/``staleness``/``losses``/
    ``mask`` are [K]; ``ids`` [K] int32 unique slot indices keying the
    pairwise secure-agg masks; ``exponent`` is the staleness discount's
    ``a`` as a runtime scalar (constant or adaptive).  Padding slots carry
    mask 0 (their deltas — and their masks — never contribute).
    ``losses`` feeds the "weighted" aggregation mode exactly as in the
    sync round; "trimmed_mean" is rejected at build time — coordinate-wise
    trimming over a staleness-discounted partial buffer has no agreed
    semantics yet (ROADMAP open item), and is incompatible with masking
    anyway.
    """
    if cfg.aggregation == "trimmed_mean":
        raise ValueError(
            "aggregation='trimmed_mean' is not supported by the async "
            "buffered commit (robust trimming over a padded, "
            "staleness-weighted buffer is undefined); use fedavg/weighted "
            "or the sync round loop")
    pipe = build_update_pipeline(cfg)

    def commit(params, server_state, deltas, weights, staleness, losses,
               mask, ids, exponent, rng):
        delta, w_eff, _ = pipe.combine(
            deltas, weights, mask, losses, rng, ids=ids,
            staleness=staleness, exponent=exponent)
        new_params, new_state = server_opt.apply(params, delta, server_state)
        metrics = {
            "delta_norm": global_norm(delta),
            "n_updates": mask.sum(),
            "mean_staleness": (staleness * mask).sum()
            / jnp.maximum(mask.sum(), 1),
            "effective_weight": w_eff.sum(),
        }
        return new_params, new_state, metrics

    return commit


def build_chunked_commit_steps(server_opt: ServerOptimizer, cfg: FLConfig,
                               async_cfg: AsyncConfig):
    """jit-able (accumulate, finalize) pair: the buffer commit split into
    C-sized chunks with ONE device call per chunk.

    ``accumulate(acc, wsum, deltas[C, ...], weights, staleness, losses,
    mask, ids, exponent, rng) -> (acc', wsum')`` folds one chunk's
    unnormalised weighted(-masked) sum into a float32 accumulator;
    ``finalize(params, server_state, acc, wsum)`` normalises by the total
    raw mass and applies the server optimizer — by the additivity of every
    pre-normalise pipeline stage this equals the single-shot
    ``build_buffer_commit_step`` over the concatenated slots in exact
    arithmetic (float summation order differs: ~1e-5 agreement, pinned by a
    property test).  Each chunk gets its own rng (the caller fold_ins the
    chunk index) and its own arange ids, so pairwise secure-agg masks
    cancel chunk-locally.  Padding slots carry mask 0 as in the single-shot
    step."""
    if cfg.aggregation == "trimmed_mean":
        raise ValueError(
            "aggregation='trimmed_mean' is not supported by the async "
            "buffered commit (robust trimming over a padded, "
            "staleness-weighted buffer is undefined); use fedavg/weighted "
            "or the sync round loop")
    pipe = build_update_pipeline(cfg)

    def accumulate(acc, wsum, deltas, weights, staleness, losses, mask, ids,
                   exponent, rng):
        summed, _, w_raw = pipe.combine_unnormalised(
            deltas, weights, mask, losses, rng, ids=ids,
            staleness=staleness, exponent=exponent)
        acc = jax.tree.map(lambda a, s: a + s.astype(a.dtype), acc, summed)
        return acc, wsum + w_raw.sum()

    def finalize(params, server_state, acc, wsum):
        delta = pipe.normalise(acc, wsum)
        new_params, new_state = server_opt.apply(params, delta, server_state)
        return new_params, new_state, {"delta_norm": global_norm(delta)}

    return accumulate, finalize
