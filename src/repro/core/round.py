"""The jit'd federated round step — the paper's Algorithm 1 lines 5-12.

``build_fl_round_step`` closes over the model loss, client/server optimizers,
aggregation strategy, and compression config, and returns one pure function:

    round_step(global_params, server_state, client_batches, weights, mask, rng)
        -> (new_params, new_server_state, metrics)

client_batches leaves are [C, H, ...] (C clients, H local steps).  ``mask``
[C] (0/1) implements deadline cutoff / fastest-k / dropouts decided host-side
by the orchestrator, so one compiled step serves every round.

Client execution modes (DESIGN.md §2):
  * parallel   — vmap over clients; client dim sharded over the batch mesh
                 axes (pod x data).  Aggregation lowers to the cross-client
                 psum — the client->server "transfer".  Hierarchical
                 compression: pod-local mean, compress, cross-pod mean.
  * sequential — lax.scan over clients; each client's local batch uses the
                 full mesh.  Required when C parallel model replicas cannot
                 fit HBM (>=100B-param archs).

All modes fold their client updates through the SAME composable stage stack
(compress -> weight -> secure_mask -> aggregate -> normalise) built once by
``repro.core.pipeline.build_update_pipeline`` — the async buffered commit
(core.async_round) closes over the identical stack.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import CompressionConfig
from repro.core.pipeline import build_update_pipeline
from repro.models import sharding as shd
from repro.optim import Optimizer, ServerOptimizer


def _axes_tuple(ax):
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


@dataclass(frozen=True)
class FLConfig:
    mode: str = "sync"                # sync (barrier rounds) | async (FedBuff
    #                                   buffered commits; see core.async_round)
    num_clients: int = 8              # clients per round (C)
    local_steps: int = 2              # H local epochs/steps per round
    client_lr: float = 0.05
    fedprox_mu: float = 0.0           # 0 -> FedAvg; >0 -> FedProx proximal term
    aggregation: str = "fedavg"       # fedavg | weighted | trimmed_mean
    client_exec: str = "parallel"     # parallel | sequential | pod_sequential
    compression: CompressionConfig = field(default_factory=CompressionConfig)
    hierarchical: bool = False        # pod-local then compressed cross-pod agg
    accum_dtype: str = "float32"      # sequential-mode delta accumulator
    use_fused_update: bool = False    # Pallas fedprox_update kernel
    secure_agg: bool = False          # commit-keyed pairwise masking: the
    #                                   server only sees masked updates whose
    #                                   masks cancel per commit (core.pipeline)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_add_scaled(a, b, s):
    return jax.tree.map(lambda x, y: x + s * y.astype(x.dtype), a, b)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def constrain_like(tree, shardings):
    """Pin a pytree (grads / deltas / accumulators) to the parameter
    shardings.  Without this GSPMD materialises weight grads REPLICATED
    (full f32 all-reduce per layer, measured 5.2 GB/layer bwd for
    mistral-large) instead of reduce-scattering to the FSDP layout —
    EXPERIMENTS.md §Perf iteration 2."""
    if shardings is None:
        return tree

    def apply(x, s):
        if s is None:
            return x
        ndim = len(s.spec) if hasattr(s, "spec") else None
        if ndim is not None and x.ndim != ndim:
            # under vmap (parallel/pod_sequential) the tracer carries a
            # mapped leading dim; constraining it to the unmapped spec would
            # force replication across the mapped mesh axis (measured: +H x
            # cross-pod grad traffic).  Skip — the batched case relies on
            # propagation instead.
            return x
        return jax.lax.with_sharding_constraint(x, s)

    return jax.tree.map(apply, tree, shardings)


def build_local_train(loss_fn: Callable, client_opt: Optimizer, cfg: FLConfig,
                      param_shardings=None):
    """Returns local_train(global_params, batches_H, rng) -> (delta, mean_loss).

    FedProx (mu>0): the proximal term mu/2 ||w - w0||^2 enters as the exact
    gradient correction mu (w - w0) — cheaper than autodiff through the norm
    and fusable into the Pallas fedprox_update kernel."""

    def local_train(global_params, batches, rng):
        opt0 = client_opt.init(global_params)

        def step(carry, xs):
            w, opt_state, loss_sum = carry
            batch, r = xs
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(w, batch)
            grads = constrain_like(grads, param_shardings)
            if cfg.use_fused_update and client_opt.name == "sgd":
                from repro.kernels import ops as kops
                w = jax.tree.map(
                    lambda wi, gi, w0i: kops.fedprox_update(
                        wi, gi, w0i, lr=cfg.client_lr, mu=cfg.fedprox_mu),
                    w, grads, global_params)
            else:
                if cfg.fedprox_mu:
                    grads = jax.tree.map(
                        lambda gi, wi, w0i: gi + cfg.fedprox_mu *
                        (wi - w0i).astype(gi.dtype),
                        grads, w, global_params)
                w, opt_state = client_opt.update(grads, opt_state, w, cfg.client_lr)
            return (w, opt_state, loss_sum + loss), None

        rngs = jax.random.split(rng, cfg.local_steps)
        (w, _, loss_sum), _ = jax.lax.scan(
            step, (global_params, opt0, jnp.float32(0.0)), (batches, rngs))
        delta = constrain_like(tree_sub(w, global_params), param_shardings)
        return delta, loss_sum / cfg.local_steps

    return local_train


def build_fl_round_step(loss_fn: Callable, client_opt: Optimizer,
                        server_opt: ServerOptimizer, cfg: FLConfig,
                        n_pods: int = 1, param_shardings=None,
                        client_spmd_axes=None):
    """client_spmd_axes: mesh axis name(s) the vmapped client (or pod) dim is
    sharded over.  Without it GSPMD replicates every per-client/per-pod
    intermediate (weights included!) across the mapped axis — measured as
    ~600 MB cross-pod all-gathers of the per-pod weight copies per layer per
    step (EXPERIMENTS.md §Perf iteration 4)."""
    if (cfg.client_exec == "parallel" and client_spmd_axes is None
            and shd.get_mesh() is not None):
        # Not just a perf footgun: vmapping clients WITHOUT spmd_axis_name
        # while the params carry full shardings makes GSPMD mis-partition
        # the scan transpose — the PRIMAL loss comes out wrong (~5e-2 on
        # the 2x2x2 mesh test before this guard; minimal trigger is a
        # down-projection whose output dim is sharded over a batch axis).
        raise ValueError(
            "client_exec='parallel' under an active mesh requires "
            "client_spmd_axes (the mesh axes the vmapped client dim is "
            "sharded over, e.g. ('pod', 'data')); vmap without "
            "spmd_axis_name over sharded params is numerically unsupported")
    local_train = build_local_train(loss_fn, client_opt, cfg, param_shardings)
    # explicit shardings no longer force the unfused stages: the fused
    # kernel entry points shard_map themselves over the active mesh
    # (kernels/ops.py), so cfg.compression.use_fused alone decides
    pipe = build_update_pipeline(cfg, n_pods=n_pods)
    C = cfg.num_clients

    # All three modes consume the SAME stage stack (core.pipeline): they
    # differ only in how client training is laid out (vmap / scan / pod
    # scan-of-vmap) and therefore in which pipeline entry point — batched
    # ``combine``, streaming ``contribution``/``accum_add``, or the cross-pod
    # ``combine_pods`` tail — folds the updates.

    # ------------------------------------------------------------- parallel
    def round_parallel(global_params, server_state, client_batches, weights,
                       mask, rng):
        def client_fn(gp, b, r):
            # the mapped client dim owns client_spmd_axes; model-internal
            # constraints must not mention them inside the vmap body
            with shd.exclude_axes(*_axes_tuple(client_spmd_axes)):
                return local_train(gp, b, r)

        rngs = jax.random.split(rng, C)
        deltas, losses = jax.vmap(client_fn, in_axes=(None, 0, 0),
                                  spmd_axis_name=client_spmd_axes)(
            global_params, client_batches, rngs)
        delta, _, _ = pipe.combine(deltas, weights, mask, losses, rng)
        new_params, new_state = server_opt.apply(global_params, delta, server_state)
        metrics = {
            "client_loss": (losses * mask).sum() / jnp.maximum(mask.sum(), 1),
            "delta_norm": global_norm(delta),
            "participation": mask.mean(),
        }
        return new_params, new_state, metrics

    # ----------------------------------------------------------- sequential
    def round_sequential(global_params, server_state, client_batches, weights,
                         mask, rng):
        zero = pipe.accum_init(global_params)
        key = pipe.mask_key(rng)
        ids = jnp.arange(C, dtype=jnp.int32)

        def client_body(carry, xs):
            acc, wsum, loss_sum = carry
            batch_c, w_c, m_c, idx, r = xs
            # Sequential-mode GSPMD audit (PR 10, mirroring the PR 8 parallel
            # -mode guard above): constraining activations over the POD axis
            # inside this scan miscompiles the BACKWARD on pod-extent>1
            # meshes — the primal loss stays bitwise-exact while mlstm-style
            # gradients (e.g. an up-projection sharded ("data","model") or
            # ("model",) on the last dim) come out O(1) wrong.  Minimal repro
            # pinned in tests/test_mesh_small.py::test_pod_axis_grad_pin.
            # Excluding POD from activation constraints (batch shards over
            # `data` only, replicated across pods) restores float-accurate
            # grads (~2e-5 worst-leaf rel, reassociation only); multi-pod
            # batch layout belongs to pod_sequential anyway.
            with shd.exclude_axes(shd.POD):
                delta, loss = local_train(global_params, batch_c, r)
            wt = pipe.client_weight(w_c, m_c, loss)
            contrib = pipe.contribution(delta, wt, r, idx=idx, ids=ids,
                                        participation=mask, key=key)
            acc = constrain_like(pipe.accum_add(acc, contrib),
                                 param_shardings)
            return (acc, wsum + wt, loss_sum + loss * m_c), None

        rngs = jax.random.split(rng, C)
        (acc, wsum, loss_sum), _ = jax.lax.scan(
            client_body, (zero, jnp.float32(0.0), jnp.float32(0.0)),
            (client_batches, weights, mask, ids, rngs))
        delta = pipe.normalise(acc, wsum)
        new_params, new_state = server_opt.apply(global_params, delta, server_state)
        metrics = {
            "client_loss": loss_sum / jnp.maximum(mask.sum(), 1),
            "delta_norm": global_norm(delta),
            "participation": mask.mean(),
        }
        return new_params, new_state, metrics

    # ------------------------------------------------------- pod_sequential
    # Clients are pinned to pods (sites): the client dim is split [P, C/P]
    # and vmapped over the `pod` mesh axis while each pod scans its own
    # clients sequentially.  During local training NO traffic crosses pods
    # (each client's batch is sharded over `data` within its pod only);
    # pods exchange exactly one compressed delta per round — the paper's
    # hierarchical HPC-site/cloud-site topology (EXPERIMENTS.md §Perf it. 4).
    # The compress stage runs inside the pod body (pod-local under GSPMD);
    # the cross-pod tail (secure-mask-between-pods -> sum -> normalise) is
    # the pipeline's ``combine_pods`` stage.
    def round_pod_sequential(global_params, server_state, client_batches,
                             weights, mask, rng):
        P = n_pods
        Cp = C // P

        def pod_body(batches_p, w_p, m_p, rng_p):
            with shd.exclude_axes(*_axes_tuple(client_spmd_axes)):
                zero = pipe.accum_init(global_params)

                accum_dt = jnp.dtype(cfg.accum_dtype)

                def client_body(carry, xs):
                    acc, wsum, loss_sum = carry
                    batch_c, w_c, m_c, r = xs
                    delta, loss = local_train(global_params, batch_c, r)
                    wt = pipe.client_weight(w_c, m_c, loss)
                    acc = pipe.accum_add(
                        acc, jax.tree.map(
                            lambda d: wt.astype(accum_dt)
                            * d.astype(accum_dt), delta))
                    return (acc, wsum + wt, loss_sum + loss * m_c), None

                rngs = jax.random.split(rng_p, Cp)
                (acc, wsum, loss_sum), _ = jax.lax.scan(
                    client_body, (zero, jnp.float32(0.0), jnp.float32(0.0)),
                    (batches_p, w_p, m_p, rngs))
                # compress the POD-level sum INSIDE the spmd-mapped body —
                # this is what crosses the slow cross-pod link (paper:
                # compress on WAN, not Infiniband), and doing it here keeps
                # the quantize/top-k work pod-local under GSPMD
                acc = pipe.compress(acc, rng_p)
                return acc, wsum, loss_sum

        resh = jax.tree.map(
            lambda x: x.reshape((P, Cp) + x.shape[1:]), client_batches)
        w2 = weights.reshape(P, Cp)
        m2 = mask.reshape(P, Cp)
        accs, wsums, loss_sums = jax.vmap(
            pod_body, spmd_axis_name=client_spmd_axes)(
            resh, w2, m2, jax.random.split(rng, P))
        delta = pipe.combine_pods(accs, wsums.sum(), rng, compressed=True)
        new_params, new_state = server_opt.apply(global_params, delta,
                                                 server_state)
        metrics = {
            "client_loss": loss_sums.sum() / jnp.maximum(mask.sum(), 1),
            "delta_norm": global_norm(delta),
            "participation": mask.mean(),
        }
        return new_params, new_state, metrics

    return {"parallel": round_parallel,
            "sequential": round_sequential,
            "pod_sequential": round_pod_sequential}[cfg.client_exec]
