"""Aggregation strategies for federated updates (paper §4.4).

Operates on stacked client deltas (leading client dim C) or on streaming
(sequential-scan) accumulators.  Supported:
  * fedavg          — mask/weight-normalised mean (weights = data sizes),
  * weighted        — dynamic weights from data size x inverse training loss,
  * trimmed_mean    — coordinate-wise trimmed mean (beyond-paper robustness,
                      §6 "adversarial behavior" future work),
plus hierarchical (pod-local then cross-pod) composition used with
compressed cross-pod transfer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def effective_weights(weights, mask, losses=None, mode: str = "fedavg"):
    """[C] weights combined with the participation mask (and losses)."""
    w = weights * mask
    if mode == "weighted" and losses is not None:
        w = w / (1.0 + jnp.maximum(losses, 0.0))
    return w


def weighted_mean(deltas, w):
    """deltas: pytree with leading client dim C;  w: [C]."""
    denom = jnp.maximum(w.sum(), 1e-12)

    def agg(d):
        wb = w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
        return (d * wb).sum(0) / denom.astype(d.dtype)

    return jax.tree.map(agg, deltas)


def trimmed_mean(deltas, mask, trim_frac: float = 0.1):
    """Coordinate-wise trimmed mean over clients.  Non-participating clients
    (mask 0) contribute zero deltas, which the trimming largely discards for
    the extreme coordinates; robust-aggregation callers should pass a full
    mask."""
    C = mask.shape[0]
    k = int(trim_frac * C)

    def agg(d):
        s = jnp.sort(d, axis=0)
        if k:
            s = s[k:C - k]
        return s.mean(0)

    return jax.tree.map(agg, deltas)
