"""Convergence test (paper Algorithm 1 line 13): Converged(M_r, M_{r+1}, eps).

The round step already returns ||Delta|| as `delta_norm`; the orchestrator
calls `converged()` host-side with a window of recent norms (a single-round
norm is noisy under partial participation)."""
from __future__ import annotations

from collections import deque


class ConvergenceMonitor:
    def __init__(self, eps: float, window: int = 3, min_rounds: int = 5):
        self.eps = eps
        self.window = window
        self.min_rounds = min_rounds
        self.norms: deque = deque(maxlen=window)
        self.rounds = 0

    def update(self, delta_norm: float) -> bool:
        self.rounds += 1
        self.norms.append(float(delta_norm))
        if self.rounds < self.min_rounds or len(self.norms) < self.window:
            return False
        return max(self.norms) < self.eps
