"""Non-IID partitioners (paper §5.2: "Each client receives samples from only
2-3 classes"; plus Dirichlet and quantity skew used in the ablations)."""
from __future__ import annotations

import numpy as np


def partition_by_class(y: np.ndarray, n_clients: int, classes_per_client: int = 2,
                       seed: int = 0) -> list[np.ndarray]:
    """LEAF/McMahan-style pathological non-IID: sort by label, deal shards."""
    rng = np.random.default_rng(seed)
    n_shards = n_clients * classes_per_client
    order = np.argsort(y, kind="stable")
    shards = np.array_split(order, n_shards)
    shard_ids = rng.permutation(n_shards)
    out = []
    for c in range(n_clients):
        take = shard_ids[c * classes_per_client:(c + 1) * classes_per_client]
        out.append(np.concatenate([shards[s] for s in take]))
    return out


def partition_dirichlet(y: np.ndarray, n_clients: int, alpha: float = 0.3,
                        seed: int = 0, min_size: int = 8) -> list[np.ndarray]:
    """Label-Dirichlet partition (Hsu et al.): smaller alpha -> more skew."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    while True:
        idx_per_client: list[list[int]] = [[] for _ in range(n_clients)]
        for c in classes:
            idx = np.where(y == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for cid, part in enumerate(np.split(idx, cuts)):
                idx_per_client[cid].extend(part.tolist())
        sizes = [len(i) for i in idx_per_client]
        if min(sizes) >= min_size:
            return [np.array(sorted(i)) for i in idx_per_client]


def partition_by_group(groups: np.ndarray, n_clients: int,
                       seed: int = 0) -> list[np.ndarray]:
    """Natural non-IID: whole groups (e.g. Shakespeare speakers) per client."""
    rng = np.random.default_rng(seed)
    uniq = rng.permutation(np.unique(groups))
    buckets = np.array_split(uniq, n_clients)
    return [np.where(np.isin(groups, b))[0] for b in buckets]


def partition_quantity_skew(n: int, n_clients: int, alpha: float = 2.0,
                            seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    props = rng.dirichlet(np.full(n_clients, alpha))
    order = rng.permutation(n)
    cuts = (np.cumsum(props) * n).astype(int)[:-1]
    return list(np.split(order, cuts))
