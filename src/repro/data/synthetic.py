"""Synthetic stand-ins for the paper's datasets (offline container).

The paper evaluates the FL *system* (scalability, fault tolerance, comm
volume) on CIFAR-10, Shakespeare (LEAF) and MedMNIST.  We reproduce the
protocol with synthetic datasets of identical shape/cardinality statistics
that are genuinely *learnable* (class-prototype images; n-gram text), so
accuracy/convergence curves are meaningful:

  * cifar10-like : 32x32x3, 10 classes — images are class prototypes +
                   structured noise.
  * medmnist-like: 28x28x1, 9 classes (PathMNIST cardinality), same recipe.
  * shakespeare-like: character stream sampled from a random-but-fixed
                   2nd-order Markov chain over a 128-char alphabet, split
                   into "speaker" shards (LEAF's natural non-IID unit).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Dataset:
    name: str
    x: np.ndarray          # images [N, ...] or token stream [N, S+1]
    y: np.ndarray          # labels [N] (classification) or None-like for LM
    num_classes: int
    kind: str              # image | text


def make_image_dataset(name: str, n: int, shape, num_classes: int,
                       noise: float = 0.35, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    protos = rng.normal(0, 1, (num_classes,) + tuple(shape)).astype(np.float32)
    # low-frequency structure: smooth prototypes along spatial dims
    for _ in range(2):
        protos = (protos + np.roll(protos, 1, axis=1) + np.roll(protos, 1, axis=2)) / 3
    y = rng.integers(0, num_classes, n)
    x = protos[y] + noise * rng.normal(0, 1, (n,) + tuple(shape)).astype(np.float32)
    return Dataset(name, x.astype(np.float32), y.astype(np.int32),
                   num_classes, "image")


def cifar10_like(n: int = 20_000, seed: int = 0,
                 noise: float = 1.7) -> Dataset:
    """Noise calibrated so a small CNN lands mid-80s% — leaving headroom for
    the FedAvg/FedProx gap to be visible (not saturated)."""
    return make_image_dataset("cifar10-like", n, (32, 32, 3), 10,
                              noise=noise, seed=seed)


def medmnist_like(n: int = 12_000, seed: int = 1,
                  noise: float = 1.5) -> Dataset:
    return make_image_dataset("medmnist-like", n, (28, 28, 1), 9,
                              noise=noise, seed=seed)


def shakespeare_like(n_seqs: int = 8_000, seq_len: int = 64, vocab: int = 128,
                     n_speakers: int = 40, seed: int = 2) -> Dataset:
    """First-order Markov text with speaker-biased continuations; y holds
    the speaker id used as the natural non-IID unit (LEAF protocol).  Each
    char admits 4 continuations; speakers prefer one of them 70% of the
    time, so next-char accuracy is learnable to ~0.7 but requires modelling
    both the chain and the (client-specific) speaker style — the non-IID
    difficulty the paper evaluates."""
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, (vocab, 4))
    speaker_pref = rng.integers(0, 4, n_speakers)
    seqs = np.zeros((n_seqs, seq_len + 1), np.int32)
    speakers = rng.integers(0, n_speakers, n_seqs)
    a = rng.integers(0, vocab, n_seqs)
    for t in range(seq_len + 1):
        col = np.where(rng.random(n_seqs) < 0.7,
                       speaker_pref[speakers],
                       rng.integers(0, 4, n_seqs))
        c = nxt[a, col]
        seqs[:, t] = c
        a = c
    return Dataset("shakespeare-like", seqs, speakers.astype(np.int32),
                   n_speakers, "text")


def lm_token_batch(rng: np.random.Generator, shape, vocab: int):
    """Random token batches for large-arch throughput/dry-run workloads."""
    toks = rng.integers(0, vocab, tuple(shape) + (1,))[..., 0]
    return toks.astype(np.int32)
