from repro.data.synthetic import (  # noqa: F401
    Dataset, cifar10_like, medmnist_like, shakespeare_like, lm_token_batch,
)
from repro.data.partition import (  # noqa: F401
    partition_by_class, partition_by_group, partition_dirichlet,
    partition_quantity_skew,
)
from repro.data.federated import FederatedDataset, VirtualFederatedDataset  # noqa: F401
