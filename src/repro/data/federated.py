"""Federated dataset: per-client data shards + round-batch sampling.

The jit'd round step consumes stacked client batches [C, H, b, ...]; this
module owns the host-side sampling that produces them, keeping raw data
"local" to each client shard (the privacy boundary of the paper: only model
updates cross client boundaries — batches never leave this object except to
the local-train step of the owning client)."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.synthetic import Dataset


@dataclass
class FederatedDataset:
    data: Dataset
    client_indices: list[np.ndarray]
    seed: int = 0
    _rngs: list = field(default_factory=list)

    def __post_init__(self):
        self._rngs = [np.random.default_rng(self.seed + 31 * c)
                      for c in range(self.num_clients)]

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)

    def _indices(self, c: int) -> np.ndarray:
        """The data shard client ``c`` owns (overridden by the virtual
        mega-fleet dataset, which maps many clients onto few shards)."""
        return self.client_indices[c]

    def _rng_for(self, c: int) -> np.random.Generator:
        return self._rngs[c]

    def client_size(self, c: int) -> int:
        return len(self._indices(c))

    @property
    def sizes(self) -> np.ndarray:
        return np.array([self.client_size(c) for c in range(self.num_clients)],
                        np.float32)

    def sample_round(self, client_ids: list[int], local_steps: int,
                     batch_size: int) -> dict:
        """Stacked batches for the round: leaves [C, H, b, ...]."""
        xs, ys = [], []
        for c in client_ids:
            idx = self._indices(c)
            take = self._rng_for(c).choice(
                idx, (local_steps, batch_size),
                replace=len(idx) < local_steps * batch_size)
            xs.append(self.data.x[take])
            ys.append(self.data.y[take] if self.data.y is not None else None)
        x = np.stack(xs)
        if self.data.kind == "text":
            return {"tokens": x[..., :-1].astype(np.int32),
                    "targets": x[..., 1:].astype(np.int32)}
        return {"image": x.astype(np.float32),
                "label": np.stack(ys).astype(np.int32)}

    def eval_batch(self, n: int = 2048, seed: int = 123) -> dict:
        """Centralised held-out evaluation batch (paper §5.3 'Model Accuracy:
        test accuracy on a centralized evaluation dataset')."""
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(self.data.x), n, replace=False)
        x = self.data.x[idx]
        if self.data.kind == "text":
            return {"tokens": x[..., :-1].astype(np.int32),
                    "targets": x[..., 1:].astype(np.int32)}
        return {"image": x.astype(np.float32),
                "label": self.data.y[idx].astype(np.int32)}


@dataclass
class VirtualFederatedDataset(FederatedDataset):
    """A mega-fleet view over a small set of base shards.

    ``n_virtual`` clients share ``len(client_indices)`` underlying data
    shards (client ``c`` samples from shard ``c % n_shards``), and the
    per-client sampling generators are materialized LAZILY — only clients
    that actually dispatch ever own a Generator, so a 100k-client fleet
    costs memory proportional to the in-flight set, not the population.
    Each lazy generator is seeded ``seed + 31 * c`` exactly like the eager
    list, so a virtual client's batch stream is identical to what a fully
    materialized dataset would have produced."""

    n_virtual: int = 0

    def __post_init__(self):
        if self.n_virtual < 1:
            raise ValueError(
                f"n_virtual must be >= 1, got {self.n_virtual}")
        self._rngs = {}                       # lazy: cid -> Generator

    @property
    def num_clients(self) -> int:
        return self.n_virtual

    def _indices(self, c: int) -> np.ndarray:
        return self.client_indices[c % len(self.client_indices)]

    def _rng_for(self, c: int) -> np.random.Generator:
        g = self._rngs.get(c)
        if g is None:
            g = self._rngs[c] = np.random.default_rng(self.seed + 31 * c)
        return g

    # ---------------------------------------------- checkpointable rng state
    def rng_states(self) -> dict:
        """Only the touched generators — the untouched ones are recomputable
        from the seed, so the checkpoint stays O(clients ever dispatched)."""
        return {str(c): g.bit_generator.state for c, g in self._rngs.items()}

    def load_rng_states(self, states: dict):
        self._rngs = {}
        for c, s in states.items():
            g = np.random.default_rng(self.seed + 31 * int(c))
            g.bit_generator.state = s
            self._rngs[int(c)] = g
