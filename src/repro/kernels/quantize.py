"""Pallas TPU kernel: blockwise symmetric quantize->dequantize.

The FL round applies this to every leaf of a model-sized update pytree each
round (paper §4.3 "gradient quantization") — an elementwise+rowreduce op that
is purely HBM-bandwidth-bound, so the kernel's job is one pass: read a VMEM
tile, compute per-block scales, round, dequantize, write back.  Straight-
through semantics (returns dequantized values; wire format is int{bits} +
one f32 scale per block, accounted in core.compression.payload_bytes).

Layout: input flattened to [R, block]; grid tiles R.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_TILE = 8


def _kernel(x_ref, o_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)              # [rows, block]
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    y = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale
    o_ref[...] = y.astype(o_ref.dtype)


def quantize_dequant_blocks(xb, bits: int, interpret: bool):
    """xb: [R, block] float; returns same shape/dtype.

    Arbitrary R: the row dim is padded here to a tile multiple (zero rows
    quantize to zero — scale falls back to 1.0 — so the pad is inert) and
    sliced back off, so odd leaf sizes route to the kernel instead of
    tripping a shape assert."""
    R, block = xb.shape
    rows = min(ROWS_TILE, R)
    rows_pad = (-R) % rows
    if rows_pad:
        xb = jnp.concatenate([xb, jnp.zeros((rows_pad, block), xb.dtype)])
    y = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=((R + rows_pad) // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + rows_pad, block), xb.dtype),
        interpret=interpret,
    )(xb)
    return y[:R] if rows_pad else y
