"""Pallas TPU kernel: fused staleness-weighted accumulate.

``acc = sum_i w_i * (1 + s_i)^(-a) * d_i`` over the slot dim of one leaf in
a SINGLE HBM pass — replacing the unfused weight-then-sum tree maps in
core/pipeline.py (one elementwise multiply materializing a full [K, ...]
intermediate, then a reduction reading it back).  The discount formula is
the FedBuff polynomial from pipeline.staleness_weights, computed in-kernel
from the raw weights so the weighted stack never touches HBM.

The slot count K rides along in the block (commit buffers are small — the
VMEM budget is K * rows * block * 4 bytes, comfortably inside 16 MB for any
realistic buffer); the grid tiles rows.  Interpret mode (CPU) evaluates the
whole stack as one grid step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_TILE = 8


def _kernel(x_ref, w_ref, s_ref, a_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)               # [K, rows, block]
    w = w_ref[...].astype(jnp.float32)               # [K, 1]
    s = s_ref[...].astype(jnp.float32)               # [K, 1]
    a = a_ref[0, 0].astype(jnp.float32)
    w_eff = w * (1.0 + s) ** (-a)                    # FedBuff discount
    o_ref[...] = (x * w_eff[:, :, None]).sum(0).astype(o_ref.dtype)


def fused_accum_blocks(xb, w, s, alpha, interpret: bool):
    """xb: [K, R, block] f32; w, s: [K, 1] f32; alpha: [1, 1] f32.
    Returns the [R, block] f32 discounted weighted sum over slots."""
    K, R, block = xb.shape
    rows = R if interpret else min(ROWS_TILE, R)
    rows_pad = (-R) % rows
    if rows_pad:
        xb = jnp.concatenate(
            [xb, jnp.zeros((K, rows_pad, block), xb.dtype)], axis=1)
    Rp = R + rows_pad
    y = pl.pallas_call(
        _kernel,
        grid=(Rp // rows,),
        in_specs=[
            pl.BlockSpec((K, rows, block), lambda i: (0, i, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, block), jnp.float32),
        interpret=interpret,
    )(xb, w, s, alpha)
    return y[:R] if rows_pad else y
