"""Pallas TPU kernel: fused FedProx local SGD update.

    w <- w - lr * (g + mu * (w - w0))

Three-operand elementwise fusion: the unfused jnp version reads w twice and
materialises (w - w0) and the corrected gradient in HBM; the kernel does one
read of each operand and one write per VMEM tile (HBM traffic 4 arrays vs 6+).
This is the inner-loop op of every client's every local step, across every
parameter of the model — the FL analogue of a fused optimizer kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024 * 8


def _kernel(w_ref, g_ref, w0_ref, o_ref, *, lr: float, mu: float):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    w0 = w0_ref[...].astype(jnp.float32)
    o_ref[...] = (w - lr * (g + mu * (w - w0))).astype(o_ref.dtype)


def fedprox_update_flat(w, g, w0, lr: float, mu: float, interpret: bool):
    """w,g,w0: flat [N] arrays padded to a TILE multiple."""
    n = w.shape[0]
    tile = min(TILE, n)
    assert n % tile == 0
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_kernel, lr=lr, mu=mu),
        grid=(n // tile,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), w.dtype),
        interpret=interpret,
    )(w, g, w0)
