"""jit'd public wrappers around the Pallas kernels.

Handles flattening/padding to tile multiples, dtype plumbing, interpret-mode
selection (interpret=True on CPU — the container validates kernel *bodies*;
TPU is the deployment target), and the custom VJP for the selective scan
(the only kernel that sits under autodiff: compression/update kernels run on
post-gradient values).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fedprox_update as _fp
from repro.kernels import fused_accum as _fa
from repro.kernels import fused_quant_mask as _fqm
from repro.kernels import quantize as _q
from repro.kernels import ref as _ref
from repro.kernels import selective_scan as _ss
from repro.kernels import topk_sparsify as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _as_blocks(x, block):
    """Blocks along the LAST dim (matches core.compression's shard-local
    grouping), then collapse leading dims to rows for the kernel grid.
    Row padding to the kernels' tile multiple happens INSIDE the block
    wrappers (quantize/topk), so any leaf size routes to the kernels."""
    L = x.shape[-1] if x.ndim else 1
    xx = x.reshape(x.shape or (1,)).astype(jnp.float32)
    pad = (-L) % block
    if pad:
        xx = jnp.pad(xx, [(0, 0)] * (xx.ndim - 1) + [(0, pad)])
    rows_shape = xx.shape[:-1] + ((L + pad) // block,)
    return xx.reshape(-1, block), (pad, rows_shape)


def _from_blocks(b, meta, shape, dtype):
    pad, rows_shape = meta
    y = b.reshape(*rows_shape, -1).reshape(*rows_shape[:-1], -1)
    if pad:
        y = y[..., :-pad]
    return y.reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def quantize_dequant(x, *, bits: int = 8, block: int = 256):
    xb, pad = _as_blocks(x, block)
    y = _q.quantize_dequant_blocks(xb, bits, _interpret())
    return _from_blocks(y, pad, x.shape, x.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def topk_sparsify(x, *, k: int, block: int = 256):
    xb, pad = _as_blocks(x, block)
    # padded zero blocks: threshold 0 keeps everything -> zeros stay zero. OK.
    y = _tk.topk_sparsify_blocks(xb, k, _interpret())
    return _from_blocks(y, pad, x.shape, x.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "mu"))
def fedprox_update(w, g, w0, *, lr: float, mu: float = 0.0):
    shape, dtype = w.shape, w.dtype
    n = int(jnp.size(w)) if not hasattr(w, "size") else w.size
    flat = lambda t: t.reshape(-1).astype(jnp.float32)
    wf, gf, w0f = flat(w), flat(g), flat(w0)
    tile = min(_fp.TILE, max(wf.shape[0], 1))
    pad = (-wf.shape[0]) % tile
    if pad:
        z = jnp.zeros((pad,), jnp.float32)
        wf, gf, w0f = (jnp.concatenate([a, z]) for a in (wf, gf, w0f))
    y = _fp.fedprox_update_flat(wf, gf, w0f, lr, mu, _interpret())
    if pad:
        y = y[:-pad]
    return y.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fused commit path (kernels/fused_accum, kernels/fused_quant_mask): the
# per-update hot loop — compress + mask + accumulate in one pass over a
# slot-stacked [K, ...] leaf.  core/pipeline.py dispatches here.
# ---------------------------------------------------------------------------

def _stack_blocks(x, block):
    """[K, ...] slot-stacked leaf -> ([K, R, block] f32, meta).  Blocks
    along the leaf's LAST dim per slot — identical block membership to
    core.compression._to_blocks, so per-block scales agree with the
    unfused stages — with leading dims collapsed into rows."""
    K = x.shape[0]
    lead = x.shape[1:]
    xx = x.reshape((K,) + (lead or (1,))).astype(jnp.float32)
    L = xx.shape[-1]
    pad = (-L) % block
    if pad:
        xx = jnp.pad(xx, [(0, 0)] * (xx.ndim - 1) + [(0, pad)])
    return xx.reshape(K, -1, block), (pad, xx.shape[1:], lead)


def _unstack_sum(y, meta, dtype):
    """[R, block] summed blocks -> the un-padded summed leaf."""
    pad, padded_shape, lead = meta
    y = y.reshape(*padded_shape[:-1], -1)
    if pad:
        y = y[..., :-pad]
    return y.reshape(lead or ()).astype(dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def fused_accum(x, w, staleness, exponent, *, block: int = 256):
    """``sum_i w_i * (1+s_i)^(-exponent) * x_i`` over the slot dim of one
    leaf in a single pass (kernels/fused_accum)."""
    xb, meta = _stack_blocks(x, block)
    K = xb.shape[0]
    wv = w.astype(jnp.float32).reshape(K, 1)
    sv = staleness.astype(jnp.float32).reshape(K, 1)
    av = jnp.asarray(exponent, jnp.float32).reshape(1, 1)
    y = _fa.fused_accum_blocks(xb, wv, sv, av, _interpret())
    return _unstack_sum(y, meta, jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "k", "block"))
def fused_plain_commit(x, w, staleness, exponent, *, bits: int, k: int,
                       block: int = 256):
    """Per-slot top-k + deterministic quantize + discounted weighted sum
    over the slot dim of one leaf, one pass (kernels/fused_quant_mask)."""
    xb, meta = _stack_blocks(x, block)
    K = xb.shape[0]
    wv = w.astype(jnp.float32).reshape(K, 1)
    sv = staleness.astype(jnp.float32).reshape(K, 1)
    av = jnp.asarray(exponent, jnp.float32).reshape(1, 1)
    y = _fqm.plain_commit_blocks(xb, wv, sv, av, bits=bits, k=k,
                                 interpret=_interpret())
    return _unstack_sum(y, meta, jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bits", "k", "block", "use_pallas"))
def fused_secure_commit(x, w_eff, seeds, coef, base, *, bits: int, k: int = 0,
                        block: int = 256, use_pallas: bool = True,
                        noise_rng=None):
    """Integer-domain secure aggregation of one slot-stacked leaf: top-k,
    commit-common-scale integer quantize, uint32 modular pairwise masks,
    sum, dequantize.  ``use_pallas=False`` (or a ``noise_rng`` for
    stochastic rounding) routes to the bit-identical jnp oracle — the
    SCHEME is the same either way; only the executor differs."""
    xb, meta = _stack_blocks(x, block)
    K = xb.shape[0]
    wv = w_eff.astype(jnp.float32).reshape(K, 1)
    if use_pallas and noise_rng is None:
        bv = jnp.asarray(base, jnp.uint32).reshape(1, 1)
        y = _fqm.secure_commit_blocks(xb, wv, seeds, coef, bv, bits=bits,
                                      k=k, interpret=_interpret())
    else:
        noise = (jax.random.uniform(noise_rng, xb.shape)
                 if noise_rng is not None else None)
        y = _ref.fused_secure_commit_ref(xb, wv, seeds, coef, base, bits,
                                         k=k, noise=noise)
    return _unstack_sum(y, meta, jnp.float32)


# ---------------------------------------------------------------------------
# selective scan with custom VJP (forward = Pallas kernel; backward = the
# reverse-time linear recurrence via associative scan)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def selective_scan_chunk(a, b, h0):
    hs, hl = _ss.selective_scan_chunk_kernel(
        a.astype(jnp.float32), b.astype(jnp.float32),
        h0.astype(jnp.float32), _interpret())
    return hs, hl


def _ss_fwd(a, b, h0):
    hs, hl = selective_scan_chunk(a, b, h0)
    return (hs, hl), (a, hs, h0)


def _ss_bwd(res, cot):
    a, hs, h0 = res
    g_hs, g_hl = cot
    # total gradient at each t: G_t = g_hs_t + a_{t+1} G_{t+1}; G_L += g_hl
    g = g_hs.at[:, -1].add(g_hl)
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)

    def combine(c1, c2):  # reverse-time scan
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    ar = jnp.flip(a_next, axis=1)
    gr = jnp.flip(g, axis=1)
    aa, bb = jax.lax.associative_scan(combine, (ar, gr), axis=1)
    G = jnp.flip(bb, axis=1)                       # [B,L,D,N]
    h_prev = jnp.concatenate([h0[:, None], hs[:, :-1]], axis=1)
    ga = G * h_prev
    gb = G
    gh0 = a[:, 0] * G[:, 0]
    return ga, gb, gh0


selective_scan_chunk.defvjp(_ss_fwd, _ss_bwd)
