"""Public wrappers around the Pallas kernels.

Handles flattening/padding to tile multiples, dtype plumbing, interpret-mode
selection (interpret=True on CPU — the container validates kernel *bodies*;
TPU is the deployment target), mesh-native execution, and the custom VJP for
the selective scan (the only kernel that sits under autodiff:
compression/update kernels run on post-gradient values).

Mesh-native fused commit (the GSPMD story)
------------------------------------------
``pallas_call`` carries no GSPMD sharding rules, so a bare kernel call under
an active mesh would force XLA to all-gather its operands.  Every fused
entry point here therefore wraps its kernel in ``shard_map`` over the active
mesh (``models.sharding.get_mesh()``/``fusion_axes()``) whenever one is
active, sharding the ROW dim of the blocked ``[K, rows, block]`` commit
stack: rows are whole last-dim blocks — the same block membership rule as
``core.compression._to_blocks`` — so per-block quantize scales and top-k
thresholds are device-local and bitwise identical to the unsharded
blocking.  The slot-dim (K) weighted sum is a purely local reduce (K is
replicated), so no collective runs inside the kernel wrapper at all.  The
one shard-dependent quantity is the secure kernel's element-index stream:
mask PRF words are derived from GLOBAL block indices
(``sharding.flat_shard_index`` offsets each shard's base), keeping uint32
mask cancellation bitwise across any mesh shape.

The mesh is read at CALL time, which is why the fused/compress entry
points are NOT wrapped in module-level ``jax.jit``: a shared jit cache
keyed only on shapes would silently replay a no-mesh trace after a mesh
became active (or vice versa).  Instead each entry point looks up a
jitted closure from an ``lru_cache`` keyed on (mesh, shard axes, static
params) — same compiled numerics as a plain ``@jax.jit``, one compiled
program per mesh configuration, no staleness.

Leaf bucketing
--------------
``fused_*_tree`` take the FLATTENED leaf list of a slot-stacked update tree
and concatenate every leaf's blocked rows into one ``[K, R_total, block]``
bucket before the kernel call: a 100+-leaf model costs one kernel launch
(and one jit cache entry) per bucket instead of one per leaf shape.  Row
concatenation preserves block membership exactly — each row is one block of
one leaf — and the row-major element index of the bucket equals the old
per-leaf ``base`` accumulation, so per-block scales, top-k thresholds and
the secure mask stream are unchanged.  ``KERNEL_LAUNCHES`` counts launches
at call time so benchmarks can report the collapse.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import fedprox_update as _fp
from repro.kernels import fused_accum as _fa
from repro.kernels import fused_quant_mask as _fqm
from repro.kernels import quantize as _q
from repro.kernels import ref as _ref
from repro.kernels import selective_scan as _ss
from repro.kernels import topk_sparsify as _tk

KERNEL_LAUNCHES = 0   # call-time pallas-launch counter (benchmarks read
#                       and reset it around a commit to see launches/call)


@functools.lru_cache(maxsize=None)
def _interpret() -> bool:
    # cached module-level lookup: the backend registry walk behind
    # jax.default_backend() is not free, and the backend cannot change
    # within a process
    return jax.default_backend() != "tpu"


def _count_launch():
    global KERNEL_LAUNCHES
    KERNEL_LAUNCHES += 1


def _mesh_axes():
    """(mesh, row-shard axes) for the active mesh, or (None, ()) when no
    mesh is active or no multi-device axis is usable.  Imported lazily:
    repro.models' package import pulls model modules that consume these
    kernels."""
    from repro.models import sharding as sh
    mesh = sh.get_mesh()
    if mesh is None:
        return None, ()
    axes = sh.fusion_axes()
    return (mesh, axes) if axes else (None, ())


def _pad_rows(xb, mult, axis):
    R = xb.shape[axis]
    pad = (-R) % mult
    if pad:
        widths = [(0, 0)] * xb.ndim
        widths[axis] = (0, pad)
        xb = jnp.pad(xb, widths)
    return xb, pad


def _shard_rows_map(mesh, axes, fn, xb):
    """Run an elementwise-by-block rows op ([R, block] -> [R, block]) with
    rows sharded over ``axes``.  Zero row padding to the shard multiple is
    a fixed point of every block kernel (scale-0 guard -> zeros stay
    zeros), so it is sliced off untouched."""
    n = math.prod(mesh.shape[a] for a in axes)
    xb, pad = _pad_rows(xb, n, 0)
    y = shard_map(fn, mesh=mesh, in_specs=(P(axes, None),),
                  out_specs=P(axes, None), check_rep=False)(xb)
    return y[:-pad] if pad else y


def _shard_rows_reduce(mesh, axes, fn, xb, *consts):
    """Run a slot-reducing rows kernel ([K, R, block] -> [R, block]) with
    rows sharded over ``axes``; scalars/seed matrices replicate.  ``fn``
    receives (xb_local, flat_shard_index, *consts) — the shard index lets
    the secure kernel derive its GLOBAL element-index base.  The slot-dim
    sum is shard-local (K replicates), so no collective is emitted."""
    from repro.models import sharding as sh
    n = math.prod(mesh.shape[a] for a in axes)
    xb, pad = _pad_rows(xb, n, 1)

    def body(xb_l, *cs):
        return fn(xb_l, sh.flat_shard_index(axes, mesh), *cs)

    y = shard_map(body, mesh=mesh,
                  in_specs=(P(None, axes, None),) + (P(),) * len(consts),
                  out_specs=P(axes, None), check_rep=False)(xb, *consts)
    return y[:-pad] if pad else y


def _as_blocks(x, block):
    """Blocks along the LAST dim (matches core.compression's shard-local
    grouping), then collapse leading dims to rows for the kernel grid.
    Row padding to the kernels' tile multiple happens INSIDE the block
    wrappers (quantize/topk), so any leaf size routes to the kernels."""
    L = x.shape[-1] if x.ndim else 1
    xx = x.reshape(x.shape or (1,)).astype(jnp.float32)
    pad = (-L) % block
    if pad:
        xx = jnp.pad(xx, [(0, 0)] * (xx.ndim - 1) + [(0, pad)])
    rows_shape = xx.shape[:-1] + ((L + pad) // block,)
    return xx.reshape(-1, block), (pad, rows_shape)


def _from_blocks(b, meta, shape, dtype):
    pad, rows_shape = meta
    y = b.reshape(*rows_shape, -1).reshape(*rows_shape[:-1], -1)
    if pad:
        y = y[..., :-pad]
    return y.reshape(shape).astype(dtype)


def quantize_dequant(x, *, bits: int = 8, block: int = 256):
    _count_launch()
    mesh, axes = _mesh_axes()
    return _quantize_dequant_c(mesh, axes, bits, block)(x)


@functools.lru_cache(maxsize=None)
def _quantize_dequant_c(mesh, axes, bits, block):
    def f(x):
        xb, meta = _as_blocks(x, block)
        run = lambda b: _q.quantize_dequant_blocks(b, bits, _interpret())
        y = run(xb) if mesh is None else _shard_rows_map(mesh, axes, run, xb)
        return _from_blocks(y, meta, x.shape, x.dtype)
    return jax.jit(f)


def topk_sparsify(x, *, k: int, block: int = 256):
    _count_launch()
    mesh, axes = _mesh_axes()
    return _topk_sparsify_c(mesh, axes, k, block)(x)


@functools.lru_cache(maxsize=None)
def _topk_sparsify_c(mesh, axes, k, block):
    def f(x):
        xb, meta = _as_blocks(x, block)
        # padded zero blocks: threshold 0 keeps everything -> zeros stay
        # zero.  OK.
        run = lambda b: _tk.topk_sparsify_blocks(b, k, _interpret())
        y = run(xb) if mesh is None else _shard_rows_map(mesh, axes, run, xb)
        return _from_blocks(y, meta, x.shape, x.dtype)
    return jax.jit(f)


@functools.partial(jax.jit, static_argnames=("lr", "mu"))
def fedprox_update(w, g, w0, *, lr: float, mu: float = 0.0):
    shape, dtype = w.shape, w.dtype
    flat = lambda t: t.reshape(-1).astype(jnp.float32)
    wf, gf, w0f = flat(w), flat(g), flat(w0)
    tile = min(_fp.TILE, max(wf.shape[0], 1))
    pad = (-wf.shape[0]) % tile
    if pad:
        z = jnp.zeros((pad,), jnp.float32)
        wf, gf, w0f = (jnp.concatenate([a, z]) for a in (wf, gf, w0f))
    y = _fp.fedprox_update_flat(wf, gf, w0f, lr, mu, _interpret())
    if pad:
        y = y[:-pad]
    return y.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fused commit path (kernels/fused_accum, kernels/fused_quant_mask): the
# per-update hot loop — compress + mask + accumulate in one pass over a
# slot-stacked [K, ...] leaf.  core/pipeline.py dispatches here through the
# bucketed fused_*_tree entry points; the per-leaf forms below serve tests
# and microbenchmarks.
# ---------------------------------------------------------------------------

def _stack_blocks(x, block):
    """[K, ...] slot-stacked leaf -> ([K, R, block] f32, meta).  Blocks
    along the leaf's LAST dim per slot — identical block membership to
    core.compression._to_blocks, so per-block scales agree with the
    unfused stages — with leading dims collapsed into rows."""
    K = x.shape[0]
    lead = x.shape[1:]
    xx = x.reshape((K,) + (lead or (1,))).astype(jnp.float32)
    L = xx.shape[-1]
    pad = (-L) % block
    if pad:
        xx = jnp.pad(xx, [(0, 0)] * (xx.ndim - 1) + [(0, pad)])
    return xx.reshape(K, -1, block), (pad, xx.shape[1:], lead)


def _unstack_sum(y, meta, dtype):
    """[R, block] summed blocks -> the un-padded summed leaf."""
    pad, padded_shape, lead = meta
    y = y.reshape(*padded_shape[:-1], -1)
    if pad:
        y = y[..., :-pad]
    return y.reshape(lead or ()).astype(dtype)


def pack_blocks(leaves, block):
    """Slot-stacked [K, ...] leaves -> ONE [K, R_total, block] bucket.

    Rows are whole blocks of one leaf each (identical membership to the
    per-leaf ``_stack_blocks``), so per-block scales, top-k thresholds and
    — through the bucket's row-major element index — the secure mask
    stream are unchanged vs. per-leaf kernel calls; only the launch count
    collapses from O(#leaves) to one.  Returns (bucket, metas, row
    counts)."""
    blocked, metas, rows = [], [], []
    for leaf in leaves:
        xb, meta = _stack_blocks(leaf, block)
        blocked.append(xb)
        metas.append(meta)
        rows.append(xb.shape[1])
    return jnp.concatenate(blocked, axis=1), metas, rows


def unpack_sums(y, metas, rows, dtype=jnp.float32):
    """[R_total, block] summed bucket -> the per-leaf summed leaves."""
    out, r0 = [], 0
    for meta, r in zip(metas, rows):
        out.append(_unstack_sum(y[r0:r0 + r], meta, dtype))
        r0 += r
    return out


def _slot_vectors(w, staleness, exponent, K):
    wv = jnp.asarray(w, jnp.float32).reshape(K, 1)
    sv = jnp.asarray(staleness, jnp.float32).reshape(K, 1)
    av = jnp.asarray(exponent, jnp.float32).reshape(1, 1)
    return wv, sv, av


def _accum_rows(mesh, axes, xb, wv, sv, av):
    if mesh is None:
        return _fa.fused_accum_blocks(xb, wv, sv, av, _interpret())
    return _shard_rows_reduce(
        mesh, axes,
        lambda xl, _, w, s, a: _fa.fused_accum_blocks(xl, w, s, a,
                                                      _interpret()),
        xb, wv, sv, av)


def _plain_rows(mesh, axes, xb, wv, sv, av, bits, k):
    if mesh is None:
        return _fqm.plain_commit_blocks(xb, wv, sv, av, bits=bits, k=k,
                                        interpret=_interpret())
    return _shard_rows_reduce(
        mesh, axes,
        lambda xl, _, w, s, a: _fqm.plain_commit_blocks(
            xl, w, s, a, bits=bits, k=k, interpret=_interpret()),
        xb, wv, sv, av)


def _secure_rows(mesh, axes, xb, wv, seeds, coef, base, bits, k):
    bv = jnp.asarray(base, jnp.uint32).reshape(1, 1)
    if mesh is None:
        return _fqm.secure_commit_blocks(xb, wv, seeds, coef, bv, bits=bits,
                                         k=k, interpret=_interpret())
    block = xb.shape[2]

    def body(xl, shard, w, sd, cf, b):
        # GLOBAL element index of this shard's row 0: each shard owns
        # local_rows whole blocks, row-major over the flat shard order
        b_l = b + shard * np.uint32(xl.shape[1] * block)
        return _fqm.secure_commit_blocks(xl, w, sd, cf, b_l, bits=bits,
                                         k=k, interpret=_interpret())

    return _shard_rows_reduce(mesh, axes, body, xb, wv, seeds, coef, bv)


def _secure_body(mesh, axes, use_pallas, bits, k, xb, wv, seeds, coef, base,
                 noise_rng):
    """Shared secure-commit core over a blocked stack: kernel vs the
    bit-identical jnp oracle (stochastic rounding or use_pallas=False)."""
    if noise_rng is not None or not use_pallas:
        noise = (jax.random.uniform(noise_rng, xb.shape)
                 if noise_rng is not None else None)
        return _ref.fused_secure_commit_ref(xb, wv, seeds, coef, base, bits,
                                            k=k, noise=noise)
    return _secure_rows(mesh, axes, xb, wv, seeds, coef, base, bits, k)


# ------------------------------------------------- bucketed tree entry points

def fused_accum_tree(leaves, w, staleness, exponent, *, block: int = 256):
    """Bucketed fused accumulate over a flattened leaf list: ONE kernel
    launch for the whole tree.  Returns the per-leaf f32 sums."""
    _count_launch()
    mesh, axes = _mesh_axes()
    return _fused_accum_tree_c(mesh, axes, block)(
        list(leaves), w, staleness, exponent)


@functools.lru_cache(maxsize=None)
def _fused_accum_tree_c(mesh, axes, block):
    def f(leaves, w, s, a):
        xb, metas, rows = pack_blocks(leaves, block)
        wv, sv, av = _slot_vectors(w, s, a, xb.shape[0])
        return unpack_sums(_accum_rows(mesh, axes, xb, wv, sv, av),
                           metas, rows)
    return jax.jit(f)


def fused_plain_commit_tree(leaves, w, staleness, exponent, *, bits: int,
                            k: int, block: int = 256):
    """Bucketed one-pass plain commit (top-k + quantize + discounted sum)
    over a flattened leaf list: ONE kernel launch for the whole tree."""
    _count_launch()
    mesh, axes = _mesh_axes()
    return _fused_plain_tree_c(mesh, axes, bits, k, block)(
        list(leaves), w, staleness, exponent)


@functools.lru_cache(maxsize=None)
def _fused_plain_tree_c(mesh, axes, bits, k, block):
    def f(leaves, w, s, a):
        xb, metas, rows = pack_blocks(leaves, block)
        wv, sv, av = _slot_vectors(w, s, a, xb.shape[0])
        return unpack_sums(_plain_rows(mesh, axes, xb, wv, sv, av, bits, k),
                           metas, rows)
    return jax.jit(f)


def fused_secure_commit_tree(leaves, w_eff, seeds, coef, *, bits: int,
                             k: int = 0, block: int = 256,
                             use_pallas: bool = True, noise_rng=None):
    """Bucketed integer-domain secure commit over a flattened leaf list.
    The bucket's row-major element index equals the old per-leaf ``base``
    accumulation (base advanced by each leaf's padded blocked size), so
    the mask stream is bitwise-identical to per-leaf calls from base 0."""
    _count_launch()
    mesh, axes = _mesh_axes()
    return _fused_secure_tree_c(mesh, axes, bits, k, block, use_pallas)(
        list(leaves), w_eff, seeds, coef, noise_rng)


@functools.lru_cache(maxsize=None)
def _fused_secure_tree_c(mesh, axes, bits, k, block, use_pallas):
    def f(leaves, w_eff, seeds, coef, noise_rng):
        xb, metas, rows = pack_blocks(leaves, block)
        wv = w_eff.astype(jnp.float32).reshape(xb.shape[0], 1)
        y = _secure_body(mesh, axes, use_pallas, bits, k, xb, wv, seeds,
                         coef, jnp.uint32(0), noise_rng)
        return unpack_sums(y, metas, rows)
    return jax.jit(f)


# ---------------------------------------------------- per-leaf entry points

def fused_accum(x, w, staleness, exponent, *, block: int = 256):
    """``sum_i w_i * (1+s_i)^(-exponent) * x_i`` over the slot dim of one
    leaf in a single pass (kernels/fused_accum); mesh-native."""
    _count_launch()
    mesh, axes = _mesh_axes()
    return _fused_accum_c(mesh, axes, block)(x, w, staleness, exponent)


@functools.lru_cache(maxsize=None)
def _fused_accum_c(mesh, axes, block):
    def f(x, w, s, a):
        xb, meta = _stack_blocks(x, block)
        wv, sv, av = _slot_vectors(w, s, a, xb.shape[0])
        return _unstack_sum(_accum_rows(mesh, axes, xb, wv, sv, av), meta,
                            jnp.float32)
    return jax.jit(f)


def fused_plain_commit(x, w, staleness, exponent, *, bits: int, k: int,
                       block: int = 256):
    """Per-slot top-k + deterministic quantize + discounted weighted sum
    over the slot dim of one leaf, one pass (kernels/fused_quant_mask);
    mesh-native — every per-block quantity is row-local, so sharded ==
    unsharded bitwise."""
    _count_launch()
    mesh, axes = _mesh_axes()
    return _fused_plain_c(mesh, axes, bits, k, block)(x, w, staleness,
                                                      exponent)


@functools.lru_cache(maxsize=None)
def _fused_plain_c(mesh, axes, bits, k, block):
    def f(x, w, s, a):
        xb, meta = _stack_blocks(x, block)
        wv, sv, av = _slot_vectors(w, s, a, xb.shape[0])
        return _unstack_sum(_plain_rows(mesh, axes, xb, wv, sv, av, bits, k),
                            meta, jnp.float32)
    return jax.jit(f)


def fused_secure_commit(x, w_eff, seeds, coef, base, *, bits: int, k: int = 0,
                        block: int = 256, use_pallas: bool = True,
                        noise_rng=None):
    """Integer-domain secure aggregation of one slot-stacked leaf: top-k,
    commit-common-scale integer quantize, uint32 modular pairwise masks,
    sum, dequantize.  ``base`` is the leaf's global element-index offset
    into the commit-wide mask stream.  ``use_pallas=False`` (or a
    ``noise_rng`` for stochastic rounding) routes to the bit-identical jnp
    oracle — the SCHEME is the same either way; only the executor
    differs."""
    _count_launch()
    mesh, axes = _mesh_axes()
    return _fused_secure_c(mesh, axes, bits, k, block, use_pallas)(
        x, w_eff, seeds, coef, jnp.asarray(base, jnp.uint32), noise_rng)


@functools.lru_cache(maxsize=None)
def _fused_secure_c(mesh, axes, bits, k, block, use_pallas):
    def f(x, w_eff, seeds, coef, base, noise_rng):
        xb, meta = _stack_blocks(x, block)
        wv = w_eff.astype(jnp.float32).reshape(xb.shape[0], 1)
        y = _secure_body(mesh, axes, use_pallas, bits, k, xb, wv, seeds,
                         coef, base, noise_rng)
        return _unstack_sum(y, meta, jnp.float32)
    return jax.jit(f)


# ---------------------------------------------------------------------------
# selective scan with custom VJP (forward = Pallas kernel; backward = the
# reverse-time linear recurrence via associative scan)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def selective_scan_chunk(a, b, h0):
    hs, hl = _ss.selective_scan_chunk_kernel(
        a.astype(jnp.float32), b.astype(jnp.float32),
        h0.astype(jnp.float32), _interpret())
    return hs, hl


def _ss_fwd(a, b, h0):
    hs, hl = selective_scan_chunk(a, b, h0)
    return (hs, hl), (a, hs, h0)


def _ss_bwd(res, cot):
    a, hs, h0 = res
    g_hs, g_hl = cot
    # total gradient at each t: G_t = g_hs_t + a_{t+1} G_{t+1}; G_L += g_hl
    g = g_hs.at[:, -1].add(g_hl)
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)

    def combine(c1, c2):  # reverse-time scan
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    ar = jnp.flip(a_next, axis=1)
    gr = jnp.flip(g, axis=1)
    aa, bb = jax.lax.associative_scan(combine, (ar, gr), axis=1)
    G = jnp.flip(bb, axis=1)                       # [B,L,D,N]
    h_prev = jnp.concatenate([h0[:, None], hs[:, :-1]], axis=1)
    ga = G * h_prev
    gb = G
    gh0 = a[:, 0] * G[:, 0]
    return ga, gb, gh0


selective_scan_chunk.defvjp(_ss_fwd, _ss_bwd)
