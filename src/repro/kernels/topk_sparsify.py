"""Pallas TPU kernel: per-block magnitude top-k sparsification.

Semantics (shared with the jnp oracle): keep every entry whose |magnitude|
is >= the k-th largest magnitude in its block, zero the rest.  Instead of a
sort (unsupported/slow on the TPU vector unit), the threshold is found by
fixed-iteration bisection on [0, max|x|] — 32 iterations reach f32-epsilon
resolution, and every iteration is a vectorised compare+popcount, which maps
cleanly onto the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_TILE = 8
N_ITERS = 32


def _kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)              # [rows, block]
    mag = jnp.abs(x)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(mag >= mid, axis=-1, keepdims=True)
        gt = cnt > k
        # keep invariant: count(>=lo) > k >= count(>=hi)... converge lo -> m_k
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, N_ITERS, body, (lo, hi))
    # lo converges to just below the k-th magnitude; keep mag >= lo while
    # breaking the ">k" overshoot by comparing against hi when exact.
    cnt_lo = jnp.sum(mag >= lo, axis=-1, keepdims=True)
    thresh = jnp.where(cnt_lo <= k, lo, hi)
    o_ref[...] = jnp.where(mag >= thresh, x, 0.0).astype(o_ref.dtype)


def topk_sparsify_blocks(xb, k: int, interpret: bool):
    """Arbitrary R: rows are padded to a tile multiple (all-zero rows keep a
    threshold of 0 and stay zero) and sliced back, so odd leaf sizes route
    to the kernel instead of tripping a shape assert."""
    R, block = xb.shape
    rows = min(ROWS_TILE, R)
    rows_pad = (-R) % rows
    if rows_pad:
        xb = jnp.concatenate([xb, jnp.zeros((rows_pad, block), xb.dtype)])
    y = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=((R + rows_pad) // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + rows_pad, block), xb.dtype),
        interpret=interpret,
    )(xb)
    return y[:R] if rows_pad else y
