"""Pallas TPU kernel: Mamba chunked selective-scan inner chunk.

Computes the diagonal linear recurrence  h_t = a_t * h_{t-1} + b_t  for one
chunk of L timesteps, emitting all intermediate states (needed for y = C.h)
plus the chunk-final state that the outer lax.scan carries.

TPU adaptation (DESIGN.md §7): the CUDA Mamba kernel streams the whole
sequence through SRAM with a warp-level scan; on TPU we instead tile
(batch x d_inner) across the grid, keep an L x d_tile x N working set in
VMEM, and run the time loop sequentially *inside* the kernel — the
recurrence is elementwise over [d_tile, N] lanes, so the VPU stays full
while HBM sees exactly one read of (a, b) and one write of hs per element.
d_inner is `model`-sharded outside the kernel (recurrent-scan sharding), so
no cross-chip traffic occurs inside a chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

D_TILE = 128


def _kernel(a_ref, b_ref, h0_ref, hs_ref, hl_ref):
    L = a_ref.shape[1]

    def body(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]          # [d_tile, N]
        hs_ref[0, t] = h
        return h

    h = jax.lax.fori_loop(0, L, body, h0_ref[0])
    hl_ref[0] = h


def selective_scan_chunk_kernel(a, b, h0, interpret: bool):
    """a, b: [B, L, D, N] f32;  h0: [B, D, N] f32.
    Returns (hs [B, L, D, N], h_last [B, D, N])."""
    B, L, D, N = a.shape
    dt = min(D_TILE, D)
    assert D % dt == 0
    hs, hl = pl.pallas_call(
        _kernel,
        grid=(B, D // dt),
        in_specs=[
            pl.BlockSpec((1, L, dt, N), lambda bi, di: (bi, 0, di, 0)),
            pl.BlockSpec((1, L, dt, N), lambda bi, di: (bi, 0, di, 0)),
            pl.BlockSpec((1, dt, N), lambda bi, di: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, dt, N), lambda bi, di: (bi, 0, di, 0)),
            pl.BlockSpec((1, dt, N), lambda bi, di: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, D, N), a.dtype),
            jax.ShapeDtypeStruct((B, D, N), a.dtype),
        ],
        interpret=interpret,
    )(a, b, h0)
    return hs, hl
