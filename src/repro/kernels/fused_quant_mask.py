"""Pallas TPU kernels: the fused commit path — compress + mask + accumulate
in ONE pass over the slot stack.

The unfused pipeline (core/pipeline.py) materializes a full model-sized
[K, ...] intermediate between every stage of
compress -> weight -> secure_mask -> aggregate.  Each stage is elementwise
or a slot reduction, i.e. pure HBM bandwidth, so fusing them into a single
kernel that reads each slot once and writes the reduced leaf once is the
whole win.  Two kernel variants over one blocked [K, rows, block] tile:

  * ``_plain_kernel`` — per-slot top-k, per-slot per-block symmetric
    quantize (identical algebra to the unfused core.compression stages),
    then the staleness-discounted weighted sum over slots.
  * ``_secure_kernel`` — per-slot top-k, ONE commit-common per-block scale,
    integer quantize, pairwise masking in the quantized INTEGER domain
    (uint32 modular arithmetic on the wire words, as in standard
    finite-ring SecAgg), sum, dequantize.  Mask words cancel EXACTLY under
    wraparound — no float cancellation error — so the output equals the
    unmasked quantized sum bit for bit while each slot's wire word stays
    uniformly masked.  This is also what lets the wire accounting charge
    quantized ring words instead of dense f32 masks (secure_agg.
    masked_payload_bytes).

The mask PRF is a portable integer avalanche hash ("lowbias32"-style) over
(pair seed, element index) — pure vector uint32 ops, so the Pallas body,
interpret mode on CPU, and the jnp oracle in kernels/ref.py share one
implementation with identical bits.  Pair seeds arrive as a symmetric
[K, K] uint32 matrix derived outside the kernel from the commit key
(secure_agg.pair_seeds); the signed coefficients sgn(id_j - id_i)*p_i*p_j
arrive as int32 in {-1, 0, +1} and are applied as two's-complement
multiplies, exact under wraparound.

Shard invariance (what makes these kernels shard_map-safe): every
per-block quantity — the plain kernel's per-slot per-block scale, the
secure kernel's commit-common per-row scale, the top-k threshold — is a
function of ONE row (one whole last-dim block), so sharding the row dim
across devices changes nothing bitwise.  The only position-dependent
quantity is the secure kernel's element index stream: ``base`` must be
the GLOBAL element index of the shard's row 0 (callers under shard_map
offset it by flat_shard_index * local_rows * block, kernels/ops.py), so
PRF mask words are derived from global positions and cancel bitwise
across any mesh shape.  ``base`` may be a traced uint32 — it is a kernel
operand, not a compile-time constant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROWS_TILE = 8
N_ITERS = 32                      # top-k threshold bisection iterations
_GOLDEN = np.uint32(0x9E3779B9)   # element-index mixing constant


def hash_u32(x):
    """"lowbias32"-style avalanche hash, uint32 -> uint32."""
    x = x ^ (x >> 16)
    x = x * np.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * np.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def mask_total_u32(seeds_row, coef_row, idx):
    """Slot i's summed pairwise masks over its K peers, uint32 modular:
    ``sum_j coef[j] * PRF(seed[j], idx)``.  ``idx`` is the [rows, block]
    global element index; coefficients enter as two's-complement uint32 so
    the signed combination is exact under wraparound."""
    cu = jax.lax.bitcast_convert_type(coef_row.astype(jnp.int32), jnp.uint32)
    bits = hash_u32(idx[None] * _GOLDEN + seeds_row[:, None, None])
    return (cu[:, None, None] * bits).sum(0, dtype=jnp.uint32)


def topk_threshold_mask(mag, k: int):
    """Boolean keep-mask for per-block magnitude top-k over the last dim:
    keep |x| >= the k-th largest magnitude, ties kept.  Fixed-iteration
    bisection on [0, max] (compare+popcount per iteration — VPU-friendly,
    no sort), same scheme as kernels/topk_sparsify."""
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        gt = jnp.sum(mag >= mid, axis=-1, keepdims=True) > k
        return jnp.where(gt, mid, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_ITERS, body, (lo, hi))
    cnt_lo = jnp.sum(mag >= lo, axis=-1, keepdims=True)
    thresh = jnp.where(cnt_lo <= k, lo, hi)
    return mag >= thresh


def _plain_kernel(x_ref, w_ref, s_ref, a_ref, o_ref, *, bits: int, k: int):
    """top-k -> per-slot per-block quantize -> discounted weighted sum."""
    x = x_ref[...].astype(jnp.float32)               # [K, rows, block]
    if k:
        x = jnp.where(topk_threshold_mask(jnp.abs(x), k), x, 0.0)
    if bits:
        qmax = 2.0 ** (bits - 1) - 1
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
        scale = jnp.where(scale == 0, 1.0, scale)
        x = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale
    w = w_ref[...].astype(jnp.float32)               # [K, 1]
    s = s_ref[...].astype(jnp.float32)               # [K, 1]
    a = a_ref[0, 0].astype(jnp.float32)
    w_eff = w * (1.0 + s) ** (-a)
    o_ref[...] = (x * w_eff[:, :, None]).sum(0).astype(o_ref.dtype)


def _secure_kernel(x_ref, w_ref, seeds_ref, coef_ref, base_ref, o_ref,
                   *, bits: int, k: int):
    """top-k -> commit-common scale -> integer quantize -> integer-domain
    pairwise mask -> sum -> dequantize.  Every slot must quantize onto ONE
    grid (the commit-common per-block scale) or the integer masks could
    not cancel in the sum."""
    x = x_ref[...].astype(jnp.float32)               # [K, rows, block]
    K, rows, block = x.shape
    if k:
        x = jnp.where(topk_threshold_mask(jnp.abs(x), k), x, 0.0)
    w = w_ref[...].astype(jnp.float32)               # [K, 1] eff. weights
    y = x * w[:, :, None]
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(y), axis=(0, 2), keepdims=True) / qmax  # [1,r,1]
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(y / scale), -qmax - 1, qmax).astype(jnp.int32)
    qu = jax.lax.bitcast_convert_type(q, jnp.uint32)
    off = (pl.program_id(0) * (rows * block)).astype(jnp.uint32)
    idx = (off + base_ref[0, 0]
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, block), 0)
           * np.uint32(block)
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, block), 1))
    total = jnp.zeros((rows, block), jnp.uint32)
    for i in range(K):     # static unroll: accumulate each slot's WIRE word
        total = total + (qu[i] + mask_total_u32(seeds_ref[i], coef_ref[i],
                                                idx))
    summed = jax.lax.bitcast_convert_type(total, jnp.int32).astype(jnp.float32)
    o_ref[...] = (summed * scale[0]).astype(o_ref.dtype)


def _rows_tiling(R: int, interpret: bool):
    """Interpret mode runs the whole stack as one grid step (a vectorised
    jnp evaluation — a Python grid loop over hundreds of tiles would crawl
    on CPU); the TPU path tiles rows for VMEM."""
    rows = R if interpret else min(ROWS_TILE, R)
    return rows, (-R) % rows


def plain_commit_blocks(xb, w, s, alpha, *, bits: int, k: int,
                        interpret: bool):
    """xb: [K, R, block] f32 -> [R, block] f32 reduced leaf."""
    K, R, block = xb.shape
    rows, rows_pad = _rows_tiling(R, interpret)
    if rows_pad:
        xb = jnp.concatenate(
            [xb, jnp.zeros((K, rows_pad, block), xb.dtype)], axis=1)
    Rp = R + rows_pad
    y = pl.pallas_call(
        functools.partial(_plain_kernel, bits=bits, k=k),
        grid=(Rp // rows,),
        in_specs=[
            pl.BlockSpec((K, rows, block), lambda i: (0, i, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, block), jnp.float32),
        interpret=interpret,
    )(xb, w, s, alpha)
    return y[:R] if rows_pad else y


def secure_commit_blocks(xb, w_eff, seeds, coef, base, *, bits: int, k: int,
                         interpret: bool):
    """xb: [K, R, block] f32; seeds: [K, K] uint32 (symmetric pair seeds);
    coef: [K, K] int32 in {-1, 0, +1}; base: [1, 1] uint32 leaf offset into
    the commit-wide element index space.  Returns [R, block] f32."""
    K, R, block = xb.shape
    rows, rows_pad = _rows_tiling(R, interpret)
    if rows_pad:
        xb = jnp.concatenate(
            [xb, jnp.zeros((K, rows_pad, block), xb.dtype)], axis=1)
    Rp = R + rows_pad
    y = pl.pallas_call(
        functools.partial(_secure_kernel, bits=bits, k=k),
        grid=(Rp // rows,),
        in_specs=[
            pl.BlockSpec((K, rows, block), lambda i: (0, i, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, K), lambda i: (0, 0)),
            pl.BlockSpec((K, K), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, block), jnp.float32),
        interpret=interpret,
    )(xb, w_eff, seeds, coef, base)
    return y[:R] if rows_pad else y
