"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _blocks_lastdim(x, block):
    """Shared grouping rule: blocks along the last dim, zero-padded."""
    shape, dtype = x.shape, x.dtype
    L = shape[-1] if x.ndim else 1
    xx = x.reshape(shape or (1,)).astype(jnp.float32)
    pad = (-L) % block
    if pad:
        xx = jnp.pad(xx, [(0, 0)] * (xx.ndim - 1) + [(0, pad)])
    return xx.reshape(*xx.shape[:-1], -1, block), pad, shape, dtype


def _unblocks(b, pad, shape, dtype):
    y = b.reshape(*b.shape[:-2], -1)
    if pad:
        y = y[..., :-pad]
    return y.reshape(shape).astype(dtype)


def quantize_dequant_ref(x, bits: int, block: int = 256):
    """Deterministic blockwise symmetric quantization round-trip."""
    b, pad, shape, dtype = _blocks_lastdim(x, block)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(b), axis=-1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    y = jnp.clip(jnp.round(b / scale), -qmax - 1, qmax) * scale
    return _unblocks(y, pad, shape, dtype)


def topk_sparsify_ref(x, k: int, block: int = 256):
    """Keep entries with |x| >= (k-th largest magnitude) per block."""
    b, pad, shape, dtype = _blocks_lastdim(x, block)
    mag = jnp.abs(b)
    thresh = -jnp.sort(-mag, axis=-1)[..., k - 1:k]
    y = jnp.where(mag >= thresh, b, 0.0)
    return _unblocks(y, pad, shape, dtype)


def fedprox_update_ref(w, g, w0, lr: float, mu: float):
    return (w.astype(jnp.float32) - lr * (g.astype(jnp.float32) +
            mu * (w.astype(jnp.float32) - w0.astype(jnp.float32)))).astype(w.dtype)


def selective_scan_chunk_ref(a, b, h0):
    """h_t = a_t h_{t-1} + b_t over the chunk dim (axis=1)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    hs = aa * h0[:, None] + bb
    return hs, hs[:, -1]
