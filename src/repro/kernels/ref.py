"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _blocks_lastdim(x, block):
    """Shared grouping rule: blocks along the last dim, zero-padded."""
    shape, dtype = x.shape, x.dtype
    L = shape[-1] if x.ndim else 1
    xx = x.reshape(shape or (1,)).astype(jnp.float32)
    pad = (-L) % block
    if pad:
        xx = jnp.pad(xx, [(0, 0)] * (xx.ndim - 1) + [(0, pad)])
    return xx.reshape(*xx.shape[:-1], -1, block), pad, shape, dtype


def _unblocks(b, pad, shape, dtype):
    y = b.reshape(*b.shape[:-2], -1)
    if pad:
        y = y[..., :-pad]
    return y.reshape(shape).astype(dtype)


def quantize_dequant_ref(x, bits: int, block: int = 256):
    """Deterministic blockwise symmetric quantization round-trip."""
    b, pad, shape, dtype = _blocks_lastdim(x, block)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(b), axis=-1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    y = jnp.clip(jnp.round(b / scale), -qmax - 1, qmax) * scale
    return _unblocks(y, pad, shape, dtype)


def topk_sparsify_ref(x, k: int, block: int = 256):
    """Keep entries with |x| >= (k-th largest magnitude) per block."""
    b, pad, shape, dtype = _blocks_lastdim(x, block)
    mag = jnp.abs(b)
    thresh = -jnp.sort(-mag, axis=-1)[..., k - 1:k]
    y = jnp.where(mag >= thresh, b, 0.0)
    return _unblocks(y, pad, shape, dtype)


def fedprox_update_ref(w, g, w0, lr: float, mu: float):
    return (w.astype(jnp.float32) - lr * (g.astype(jnp.float32) +
            mu * (w.astype(jnp.float32) - w0.astype(jnp.float32)))).astype(w.dtype)


def fused_accum_ref(xb, w, s, alpha):
    """Oracle for kernels/fused_accum over a blocked [K, R, block] stack:
    ``sum_i w_i * (1 + s_i)^(-alpha) * x_i``.  w, s are [K, 1]."""
    w_eff = (w.astype(jnp.float32)
             * (1.0 + s.astype(jnp.float32)) ** (-alpha))
    return (xb.astype(jnp.float32) * w_eff[:, :, None]).sum(0)


def _topk_block_sort(x, k: int):
    """Ground-truth per-block top-k (sort threshold, ties kept) over the
    last dim — the same semantics core.compression.topk_sparsify uses."""
    mag = jnp.abs(x)
    thresh = -jnp.sort(-mag, axis=-1)[..., k - 1:k]
    return jnp.where(mag >= thresh, x, 0.0)


def fused_plain_commit_ref(xb, w, s, alpha, bits: int, k: int = 0):
    """Oracle for fused_quant_mask._plain_kernel over the blocked
    [K, R, block] stack: per-slot top-k -> per-slot per-block symmetric
    quantize -> staleness-discounted weighted sum over slots."""
    x = xb.astype(jnp.float32)
    if k:
        x = _topk_block_sort(x, k)
    if bits:
        qmax = 2.0 ** (bits - 1) - 1
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
        scale = jnp.where(scale == 0, 1.0, scale)
        x = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale
    return fused_accum_ref(x, w, s, alpha)


def fused_secure_commit_ref(xb, w_eff, seeds, coef, base, bits: int,
                            k: int = 0, noise=None):
    """Oracle for fused_quant_mask._secure_kernel: integer-domain SecAgg
    over a blocked [K, R, block] stack.  Weighted slot values quantize onto
    ONE commit-common per-block grid, the int32 wire words pick up uint32
    modular pairwise masks (exact cancellation in the sum), and the summed
    word dequantizes back through the common scale.

    ``noise`` ([K, R, block] uniform[0,1)) switches round() to stochastic
    rounding ``floor(y/S + u)`` — the jnp fallback the pipeline uses when
    ``stochastic_rounding`` is on (the Pallas kernel is deterministic).
    Masks are additive integers either way, so cancellation is unaffected.
    """
    from repro.kernels import fused_quant_mask as fqm

    x = xb.astype(jnp.float32)
    K, R, block = x.shape
    if k:
        x = _topk_block_sort(x, k)
    y = x * w_eff.astype(jnp.float32)[:, :, None]
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(y), axis=(0, 2), keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    yq = y / scale
    q = jnp.floor(yq + noise) if noise is not None else jnp.round(yq)
    q = jnp.clip(q, -qmax - 1, qmax).astype(jnp.int32)
    qu = jax.lax.bitcast_convert_type(q, jnp.uint32)
    idx = (jnp.asarray(base, jnp.uint32)
           + jnp.arange(R * block, dtype=jnp.uint32).reshape(R, block))
    total = jnp.zeros((R, block), jnp.uint32)
    for i in range(K):
        total = total + (qu[i]
                         + fqm.mask_total_u32(seeds[i], coef[i], idx))
    summed = jax.lax.bitcast_convert_type(total, jnp.int32).astype(jnp.float32)
    return summed * scale[0]


def selective_scan_chunk_ref(a, b, h0):
    """h_t = a_t h_{t-1} + b_t over the chunk dim (axis=1)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    hs = aa * h0[:, None] + bb
    return hs, hs[:, -1]
