"""Pallas TPU kernels for the paper's compute hot-spots (§4.3 compression
pipeline + the Mamba selective scan), validated interpret=True on CPU:

  quantize.py        blockwise int8/int4 symmetric quantize->dequantize
  topk_sparsify.py   per-block magnitude top-k (bisection threshold, VPU)
  fedprox_update.py  fused w <- w - lr*(g + mu*(w - w0))
  selective_scan.py  chunked Mamba recurrence (VMEM-resident time loop)

ops.py: jit'd public wrappers (padding, dtype, custom VJP for the scan).
ref.py: pure-jnp oracles — the correctness contract for tests.
"""
from repro.kernels import ops, ref  # noqa: F401
