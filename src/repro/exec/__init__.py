from repro.exec.backend import (BACKEND_NAMES, ClientExecution,  # noqa: F401
                                ClosedFormBackend, ExecutionBackend,
                                SchedulerBackend, make_backend)
