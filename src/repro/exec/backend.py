"""Execution backends: ONE source of simulated time for client training.

Both orchestrators used to derive every client round time straight from the
closed-form lognormal model in ``orchestrator.straggler`` while the
SLURM/K8s scheduler simulation sat in a silo, and spot preemptions were an
independent ``FaultInjector`` coin flip.  The ``ExecutionBackend`` interface
makes timing/placement pluggable:

  * ``ClosedFormBackend`` — wraps ``simulate_round_times`` (compute +
    transfer + lognormal contention).  Zero queue wait, clients always run
    on their home site.  The fast default; bit-identical to the pre-backend
    behaviour.
  * ``SchedulerBackend`` — dispatches each client attempt as a ``JobSpec``
    through a ``HybridAdapter``, so the attempt's wall time additionally
    includes queue wait behind a finite SLURM partition, elastic HPC→cloud
    overflow, K8s autoscaling, and spot preemptions that ORIGINATE FROM THE
    ADAPTER's reclaim events (``handles_preemption``) instead of an injector
    draw.  Placement (the site the job actually ran on) feeds the comm
    ledger and the RoundLog/CommitLog queue-wait/overflow columns.

Both backends draw the underlying work duration from the SAME
``simulate_round_times`` call against the orchestrator's RNG, so with an
uncontended pool, zero queue noise and no preemption the two backends
produce identical times — the equivalence ``tests/test_exec_backend.py``
pins to 1e-6.

Determinism/checkpointing: the scheduler adapters fix every random draw at
submit time and stamp exact terminal deadlines, so a job's trajectory is
fully determined by the already-submitted job set.  ``SchedulerBackend``
exploits that twice — arrival lookahead steps a *clone* of the pool through
its exact event times (the real pool replays the same trajectory as the
orchestrator clock catches up), and ``state()``/``set_state()`` serialise
the pool for bit-identical kill/``--resume``.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass

import numpy as np

from repro.orchestrator.straggler import StragglerPolicy, simulate_round_times
from repro.sched.adapter import JobState, TERMINAL_STATES, JobSpec
from repro.sched.hybrid import HybridAdapter

BACKEND_NAMES = ("closed-form", "scheduler")


@dataclass
class ClientExecution:
    """Where and when one client-training attempt actually ran.

    ``work_s`` is the fault-free closed-form attempt duration (the recovery
    baseline); ``run_s`` is the time the job actually held its node — equal
    to the scheduled runtime for completed jobs, truncated at the strike for
    preempted ones."""
    work_s: float
    run_s: float
    queue_wait_s: float = 0.0
    full_run_s: float = 0.0        # scheduled runtime had nothing struck it
    site: str = ""                 # placement site ("hpc" | "cloud")
    job_id: str = ""
    preempted: bool = False        # adapter-origin spot reclaim
    overflowed: bool = False       # placed off its requested site

    def __post_init__(self):
        if not self.full_run_s:
            self.full_run_s = self.run_s

    @property
    def duration_s(self) -> float:
        """Dispatch -> arrival (completion, or the preemption strike)."""
        return self.queue_wait_s + self.run_s

    @property
    def fault_free_s(self) -> float:
        """Dispatch -> arrival had the attempt not been preempted."""
        return self.queue_wait_s + self.full_run_s

    @property
    def frac_done(self) -> float:
        """Fraction of the attempt's work completed at the strike."""
        if not self.preempted:
            return 1.0
        return self.run_s / self.full_run_s if self.full_run_s else 0.0


class ExecutionBackend(abc.ABC):
    """Pluggable simulated-execution layer shared by both orchestrators."""

    name: str = "?"
    #: True when spot preemptions are produced by this backend's event
    #: stream — the FaultInjector must then NOT draw its own preempt dice.
    handles_preemption: bool = False

    def bind(self, rng: np.random.Generator, straggler: StragglerPolicy):
        """Attach the orchestrator's RNG + straggler policy.  Called once in
        ``__post_init__``; both backends draw the base work duration from
        this stream so their draws stay aligned."""
        self.rng = rng
        self.straggler = straggler
        return self

    def _work_s(self, client, flops_per_client: float,
                payload_bytes: int) -> float:
        return float(simulate_round_times(
            [client], flops_per_client, payload_bytes, self.rng,
            self.straggler)[0])

    def begin_window(self, n: int):
        """Window hint: the orchestrator expects up to ``n`` dispatches
        before the next commit.  The base behaviour reserves an ``n``-sized
        block on the bound RNG (when it supports block reservation — the
        event-window engine's ``BlockedGenerator``), so all of the window's
        contention-noise draws come from ONE vectorized call.  Backends may
        additionally amortize per-dispatch bookkeeping across the window."""
        r = getattr(self, "rng", None)
        if hasattr(r, "reserve"):
            r.reserve(n)

    @abc.abstractmethod
    def execute(self, client, flops_per_client: float, payload_bytes: int,
                now: float) -> ClientExecution:
        """One async dispatch: simulate a full client attempt starting at
        sim-time ``now``."""

    @abc.abstractmethod
    def resume(self, client, remaining_work_s: float,
               now: float) -> ClientExecution:
        """Re-enqueue only the REMAINING work of a faulted attempt (the
        partial-progress recovery path).  Draws no new work randomness."""

    def execute_round(self, clients: list, flops_per_client: float,
                      payload_bytes: int, now: float) -> list[ClientExecution]:
        """One sync barrier round: all ``clients`` dispatch at ``now``."""
        return [self.execute(c, flops_per_client, payload_bytes, now)
                for c in clients]

    def execute_batch(self, clients: list, flops_per_client: float,
                      payload_bytes: int, now: float) -> list[ClientExecution]:
        """N async dispatches at the same instant (the concurrency top-up of
        the batched mega-fleet engine).  Must consume every RNG stream in
        the same per-client order as N sequential ``execute`` calls — the
        engine-equivalence suite pins batched == per-event bit-identically.
        The base implementation IS the sequential loop; backends override it
        to amortise the per-dispatch overhead."""
        return [self.execute(c, flops_per_client, payload_bytes, now)
                for c in clients]

    def release(self, job_id: str, t: float):
        """The orchestrator observed this attempt's fate at sim-time ``t``
        and is done with it (fault arrivals cancel the backing job)."""

    def end_round(self, t: float):
        """Sync barrier closed at sim-time ``t``: straggler jobs cut off by
        the mitigation are abandoned."""

    # ------------------------------------------------- checkpointable state
    def state(self) -> dict:
        return {}

    def set_state(self, s: dict):
        if s:
            raise ValueError(f"{self.name} backend carries no state but the "
                             f"checkpoint holds {sorted(s)}")


class ClosedFormBackend(ExecutionBackend):
    """The pre-backend behaviour: pure closed-form times, no pool."""

    name = "closed-form"
    handles_preemption = False

    def execute(self, client, flops_per_client, payload_bytes, now):
        w = self._work_s(client, flops_per_client, payload_bytes)
        return ClientExecution(work_s=w, run_s=w, site=client.site)

    def execute_round(self, clients, flops_per_client, payload_bytes, now):
        # one vectorised call for the whole cohort: consumes the RNG exactly
        # as the legacy `simulate_round_times(clients, ...)` did
        times = simulate_round_times(clients, flops_per_client, payload_bytes,
                                     self.rng, self.straggler)
        return [ClientExecution(work_s=float(t), run_s=float(t), site=c.site)
                for c, t in zip(clients, times)]

    def execute_batch(self, clients, flops_per_client, payload_bytes, now):
        # one vectorised draw for the whole batch: `simulate_round_times`
        # draws one lognormal per client in list order, exactly what N
        # sequential execute() calls would have pulled from the stream
        times = simulate_round_times(clients, flops_per_client, payload_bytes,
                                     self.rng, self.straggler)
        return [ClientExecution(work_s=float(t), run_s=float(t), site=c.site)
                for c, t in zip(clients, times)]

    def resume(self, client, remaining_work_s, now):
        return ClientExecution(work_s=remaining_work_s,
                               run_s=remaining_work_s, site=client.site)


class SchedulerBackend(ExecutionBackend):
    """Client attempts become jobs in a simulated SLURM+K8s hybrid pool."""

    name = "scheduler"
    handles_preemption = True

    def __init__(self, hybrid: HybridAdapter | None = None):
        self.hybrid = hybrid or HybridAdapter()
        self._open_round_jobs: list[str] = []
        self._prune_credit = 0

    # ------------------------------------------------------------- dispatch
    def _spec_for(self, client) -> JobSpec:
        return JobSpec(
            name=f"fl-client-{client.cid}",
            command=f"python -m repro.worker --client-id {client.cid}",
            gpus_per_node=1 if client.profile.compute_tflops > 4 else 0,
            mem_gb=int(client.profile.memory_gb),
            site=client.site,
            preemptible=client.profile.spot)

    def begin_window(self, n: int):
        """One terminal-job GC for the whole window instead of one per
        submit.  ``prune_terminal`` only deletes TERMINAL jobs from the
        adapters' tables — it never changes a scheduling decision — so
        deferring it is trajectory-invariant (a mid-window checkpoint may
        carry a few extra terminal jobs; they are pruned on first use)."""
        super().begin_window(n)
        self.hybrid.prune_terminal()
        self._prune_credit = int(n)

    def _submit(self, client, work_s: float, now: float):
        if self._prune_credit > 0:
            self._prune_credit -= 1
        else:
            self.hybrid.prune_terminal()
        self.hybrid.advance_to(now)
        h = self.hybrid.submit(self._spec_for(client), work_s=work_s)
        self.hybrid.advance_to(self.hybrid.clock)   # settle: start if room
        return h

    def _read(self, twin: HybridAdapter, job_id: str, work_s: float,
              submit_t: float) -> ClientExecution:
        adapter = twin._route[job_id]
        h = adapter.jobs[job_id]
        full_run = adapter._runtime_s(h)
        preempted = h.state == JobState.PREEMPTED
        return ClientExecution(
            work_s=work_s,
            run_s=(h.end_time - h.start_time) if preempted else full_run,
            queue_wait_s=h.start_time - submit_t,
            full_run_s=full_run,
            site=twin.site_of(job_id),
            job_id=job_id,
            preempted=preempted,
            overflowed=twin.site_of(job_id) != h.spec.site)

    @staticmethod
    def _step_until(twin: HybridAdapter, job_ids: list[str]):
        """Advance the clone through its exact event times until every
        listed job is terminal."""
        def alive():
            return [j for j in job_ids
                    if twin.poll(j) not in TERMINAL_STATES]
        while alive():
            nxt = twin.next_event_time()
            if nxt is None:
                raise RuntimeError(
                    f"jobs {alive()} can never start: the pool is idle but "
                    f"too small for their node requests")
            twin.advance_to(nxt)

    def _lookahead(self, job_ids: list[str], works: list[float],
                   now: float) -> list[ClientExecution]:
        # the adapters fix all randomness at submit and start strictly FIFO,
        # so this clone's trajectory IS the real pool's future for these jobs
        twin = self.hybrid.clone()
        self._step_until(twin, job_ids)
        return [self._read(twin, jid, w, now)
                for jid, w in zip(job_ids, works)]

    def execute(self, client, flops_per_client, payload_bytes, now):
        w = self._work_s(client, flops_per_client, payload_bytes)
        h = self._submit(client, w, now)
        # queue wait is measured from the DISPATCH instant: if the pool
        # clock had already drifted past `now` the extra lag is queue wait
        return self._lookahead([h.job_id], [w], now)[0]

    def resume(self, client, remaining_work_s, now):
        h = self._submit(client, remaining_work_s, now)
        return self._lookahead([h.job_id], [remaining_work_s], now)[0]

    def execute_batch(self, clients, flops_per_client, payload_bytes, now):
        """N dispatches at ``now`` with ONE pool-clone lookahead.

        Work draws are batched (same per-client stream order as sequential
        execute calls); each job still goes through the exact per-job
        submit+settle sequence, so the adapters' submit-time randomness and
        FIFO start decisions are byte-for-byte those of the per-event loop.
        The lookahead clone is read-only and starts are strictly FIFO with
        all randomness fixed at submit, so reading job i from a twin that
        also carries the later-submitted jobs i+1..N yields the same
        trajectory as N separate single-job lookaheads — that equivalence
        is pinned by the engine-equivalence suite."""
        works = [float(t) for t in simulate_round_times(
            clients, flops_per_client, payload_bytes, self.rng,
            self.straggler)]
        handles = [self._submit(c, w, now) for c, w in zip(clients, works)]
        return self._lookahead([h.job_id for h in handles], works, now)

    def execute_round(self, clients, flops_per_client, payload_bytes, now):
        works = [float(t) for t in simulate_round_times(
            clients, flops_per_client, payload_bytes, self.rng,
            self.straggler)]
        self.hybrid.prune_terminal()
        self.hybrid.advance_to(now)
        handles = [self.hybrid.submit(self._spec_for(c), work_s=w)
                   for c, w in zip(clients, works)]
        self.hybrid.advance_to(self.hybrid.clock)
        self._open_round_jobs = [h.job_id for h in handles]
        return self._lookahead(self._open_round_jobs, works, now)

    # ------------------------------------------------------------- teardown
    def release(self, job_id: str, t: float):
        if not job_id:
            return
        self.hybrid.advance_to(t)
        # the job may have gone terminal on its own (e.g. pool-preempted
        # before an injector fault's strike time) and been pruned since
        if job_id in self.hybrid._route \
                and self.hybrid.poll(job_id) not in TERMINAL_STATES:
            self.hybrid.cancel(job_id)

    def end_round(self, t: float):
        self.hybrid.advance_to(t)
        for jid in self._open_round_jobs:
            if jid in self.hybrid._route \
                    and self.hybrid.poll(jid) not in TERMINAL_STATES:
                self.hybrid.cancel(jid)
        self._open_round_jobs = []

    # ------------------------------------------------- checkpointable state
    def state(self) -> dict:
        return {"hybrid": self.hybrid.state_dict(),
                "config": self.hybrid.config_dict(),
                "open_round_jobs": list(self._open_round_jobs)}

    def set_state(self, s: dict):
        if not s:
            raise ValueError(
                "checkpoint carries no scheduler-backend state; it was "
                "written under --exec-backend closed-form")
        cfg = s.get("config")
        if cfg is not None and cfg != self.hybrid.config_dict():
            raise ValueError(
                f"checkpoint pool config {cfg} != this backend's "
                f"{self.hybrid.config_dict()}; restore requires an "
                f"identically configured pool")
        self.hybrid.load_state(s["hybrid"])
        self._open_round_jobs = list(s.get("open_round_jobs", []))


def make_backend(name: str, hybrid: HybridAdapter | None = None,
                 **hybrid_kw) -> ExecutionBackend:
    """Factory for ``--exec-backend``.  ``hybrid_kw`` (``slurm=``, ``k8s=``,
    ``overflow_to_cloud=``) builds the pool when one isn't passed."""
    if name == "closed-form":
        return ClosedFormBackend()
    if name == "scheduler":
        return SchedulerBackend(hybrid or HybridAdapter(**hybrid_kw))
    raise ValueError(f"unknown execution backend {name!r}; "
                     f"expected one of {BACKEND_NAMES}")
