"""Federated inference driver (paper §6 'Federated inference' future work,
implemented here): serve a model with batched autoregressive decoding using
the same prefill/decode steps the dry-run lowers.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 16 --gen 8

On CPU this runs the reduced config; on a TPU pod the full config uses the
sharded serve path (sequence-sharded KV cache, gather_tokens MoE dispatch).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    m = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = m.init(rng)
    B, S0, T = args.batch, args.prompt_len, args.gen
    s_max = S0 + T
    shape = (B, S0, cfg.n_codebooks) if cfg.n_codebooks else (B, S0)
    prompt = jax.random.randint(rng, shape, 0, cfg.vocab, jnp.int32)
    batch = {"tokens": prompt}
    patches = None
    if cfg.cross_attn_every:
        patches = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
        batch["patches"] = patches

    prefill = jax.jit(lambda p, b: m.prefill(p, b, s_max))
    decode = jax.jit(m.decode_step)

    t0 = time.time()
    logits, state = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    for t in range(T):
        rng, key = jax.random.split(rng)
        if args.temperature > 0:
            tok = jax.random.categorical(key, logits / args.temperature, axis=-1)
        else:
            tok = logits.argmax(-1)
        toks.append(np.asarray(tok))
        logits, state = decode(params, state, tok.astype(jnp.int32),
                               jnp.int32(S0 + t), patches)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = np.stack(toks, axis=1)
    print(f"arch={cfg.name} prefill({B}x{S0})={t_prefill*1e3:.1f}ms "
          f"decode {T} steps={t_decode*1e3:.1f}ms "
          f"({t_decode/T*1e3:.1f} ms/tok)")
    print("generated token ids:\n", gen[..., 0] if gen.ndim == 3 else gen)


if __name__ == "__main__":
    main()
