"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because dryrun.py must set
XLA_FLAGS before any jax initialisation.

Mesh semantics (DESIGN.md §2): `pod` = site (HPC cluster / cloud region),
`data` = federated-client / batch axis inside a site, `model` = tensor /
expert / sequence parallel axis inside a client.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    n = n_devices or len(jax.devices())
    if n >= 8:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    if n >= 4:
        return jax.make_mesh((2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))
