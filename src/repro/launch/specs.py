"""ShapeDtypeStruct input stand-ins + sharding-spec plumbing for AOT
lowering (no device allocation) — deliverable (e)/(f) machinery.

`sanitize_specs` is the single divisibility gate: any dim whose size does not
divide by the mesh extent of its logical axes falls back to replicated (e.g.
batch=1 in long_500k, kv_heads < 16, the 36-head starcoder2 attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import InputShape
from repro.configs.base import ModelConfig
from repro.models import sharding as sh
from repro.models.transformer import LM


def resolve_logical(logical, mesh: Mesh):
    return tuple(sh.resolve(e, mesh) for e in logical)


def sanitize_entry(shape, logical, mesh: Mesh) -> P:
    entries = []
    for dim, ent in enumerate(logical):
        r = sh.resolve(ent, mesh)
        if r is None:
            entries.append(None)
            continue
        axes = (r,) if isinstance(r, str) else tuple(r)
        extent = int(np.prod([mesh.shape[a] for a in axes]))
        if shape[dim] % extent != 0:
            entries.append(None)
        else:
            entries.append(r)
    return P(*entries)


def sanitize_specs(shape_tree, logical_tree, mesh: Mesh):
    """Tree of NamedShardings matching shape_tree's structure.  The logical
    tree has tuple leaves, so flatten it with an explicit is_leaf."""
    s_flat, treedef = jax.tree.flatten(shape_tree)
    l_flat, _ = jax.tree.flatten(logical_tree,
                                 is_leaf=lambda x: isinstance(x, tuple))
    assert len(s_flat) == len(l_flat), (len(s_flat), len(l_flat))
    out = [NamedSharding(mesh, sanitize_entry(s.shape, l, mesh))
           for s, l in zip(s_flat, l_flat)]
    return jax.tree.unflatten(treedef, out)


def _tok_dtype():
    return jnp.int32


def train_client_batch_specs(cfg: ModelConfig, shape: InputShape,
                             num_clients: int, local_steps: int):
    """[C, H, b, ...] stacked client batches + logical shardings."""
    C, H = num_clients, local_steps
    b = shape.global_batch // C
    S = shape.seq_len
    sds = jax.ShapeDtypeStruct
    tok_shape = (C, H, b, S, cfg.n_codebooks) if cfg.n_codebooks else (C, H, b, S)
    specs = {"tokens": sds(tok_shape, _tok_dtype()),
             "targets": sds(tok_shape, _tok_dtype())}
    # parallel mode shards the client dim C over BATCH; sequential shards the
    # within-client batch b.  sanitize_specs drops whichever does not divide.
    tok_logical = ((sh.BATCH, None, None, None, None) if cfg.n_codebooks
                   else (sh.BATCH, None, None, None))
    seq_logical = ((None, None, sh.BATCH, None, None) if cfg.n_codebooks
                   else (None, None, sh.BATCH, None))
    logical = {"tokens": tok_logical, "targets": tok_logical}
    logical_seq = {"tokens": seq_logical, "targets": seq_logical}
    if cfg.cross_attn_every:
        specs["patches"] = sds((C, H, b, cfg.n_patches, cfg.d_model),
                               jnp.dtype(cfg.dtype))
        logical["patches"] = (sh.BATCH, None, None, None, sh.MODEL)
        logical_seq["patches"] = (None, None, sh.BATCH, None, sh.MODEL)
    return specs, logical, logical_seq


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape):
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    specs = {"tokens": sds(tok_shape, _tok_dtype())}
    logical = {"tokens": (sh.BATCH,) + (None,) * (len(tok_shape) - 1)}
    if cfg.cross_attn_every:
        specs["patches"] = sds((B, cfg.n_patches, cfg.d_model),
                               jnp.dtype(cfg.dtype))
        logical["patches"] = (sh.BATCH, None, sh.MODEL)
    return specs, logical


def decode_inputs_specs(cfg: ModelConfig, shape: InputShape, model: LM):
    """(token, pos, state, patches?) specs for serve_step."""
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, cfg.n_codebooks) if cfg.n_codebooks else (B,)
    token = sds(tok_shape, _tok_dtype())
    token_logical = (sh.BATCH,) + (None,) * (len(tok_shape) - 1)
    state = model.decode_state_specs(B, S)
    state_logical = model.state_logical_specs(B, S)
    patches = patches_logical = None
    if cfg.cross_attn_every:
        patches = sds((B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
        patches_logical = (sh.BATCH, None, sh.MODEL)
    return (token, token_logical, state, state_logical, patches,
            patches_logical)
