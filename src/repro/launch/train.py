"""Federated training launcher — the deployable entry point (deliverable b).

    PYTHONPATH=src python -m repro.launch.train \
        --dataset cifar10 --algo fedprox --rounds 100 \
        --clients-pool 60 --clients-per-round 20 --local-steps 5 \
        --quantize-bits 8 --topk-frac 0.1 --fastest-k 16 \
        --checkpoint-dir ckpts/run1 --render-jobs artifacts/jobs

Defaults mirror the paper's §5.1 configuration (60-node hybrid fleet,
20 clients/round, 5 local epochs, 100 rounds).  --render-jobs additionally
emits the sbatch scripts / pod manifests the scheduler adapter would submit
for each selected client (deployability artifact).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointManager, CheckpointManager
from repro.configs import get_config
from repro.core import AsyncConfig, CompressionConfig, FLConfig
from repro.data import (FederatedDataset, cifar10_like, medmnist_like,
                        partition_by_class, partition_by_group,
                        shakespeare_like)
from repro.models import build_model
from repro.models.cnn import CIFAR_CNN, CNN, MEDMNIST_CNN
from repro.core import payload_bytes
from repro.exec import BACKEND_NAMES, make_backend
from repro.comm import LinkClass, WANTopology
from repro.orchestrator import (AsyncOrchestrator, BatchedAsyncOrchestrator,
                                CohortFleet, EventWindowOrchestrator,
                                FaultConfig, HierarchicalOrchestrator,
                                Orchestrator, StragglerPolicy,
                                equivalent_preempt_rate_per_min,
                                make_facilities, make_hybrid_fleet,
                                split_fleet)
from repro.orchestrator.straggler import expected_attempt_s
from repro.sched import HybridAdapter, JobSpec, K8sAdapter, SlurmAdapter

# --engine auto crossover: below this fleet size the per-event engine wins
# (no vmap padding / bucketing overhead on tiny fleets — see the committed
# artifacts/bench/table_megafleet.json sweep: legacy 3.1 vs batched 3.9
# wall_per_sim_s at 100 clients, batched/window ~11x faster from 1k up)
AUTO_ENGINE_THRESHOLD = 300


def resolve_engine(engine: str, fleet) -> str:
    """Map --engine auto to a concrete engine from the fleet size."""
    if engine != "auto":
        return engine
    if isinstance(fleet, CohortFleet) or len(fleet) >= AUTO_ENGINE_THRESHOLD:
        return "window"
    return "legacy"


def _staleness_exp(v: str):
    if v == "adaptive":
        return v
    try:
        return float(v)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected a float or 'adaptive', got {v!r}")


def build_task(name: str, n_clients: int, seed: int):
    if name == "cifar10":
        ds = cifar10_like(n=20_000, seed=seed)
        parts = partition_by_class(ds.y, n_clients, 2, seed=seed)
        model = CNN(CIFAR_CNN)
    elif name == "medmnist":
        ds = medmnist_like(n=12_000, seed=seed)
        parts = partition_by_class(ds.y, n_clients, 3, seed=seed)
        model = CNN(MEDMNIST_CNN)
    elif name == "shakespeare":
        ds = shakespeare_like(n_seqs=8000, seq_len=64, n_speakers=2 * n_clients,
                              seed=seed)
        parts = partition_by_group(ds.y, n_clients, seed=seed)
        model = build_model(get_config("paper-charlm"))
    else:
        raise ValueError(name)
    fed = FederatedDataset(ds, parts, seed=seed)
    params = model.init(jax.random.PRNGKey(seed))
    if hasattr(model, "accuracy"):
        eval_batch = jax.tree.map(jnp.asarray, fed.eval_batch(1024))
        acc = jax.jit(model.accuracy)
        eval_fn = lambda p: acc(p, eval_batch)
    else:
        eval_fn = None
    return fed, model, params, eval_fn


def render_jobs(fleet, out_dir: Path):
    hy = HybridAdapter()
    out_dir.mkdir(parents=True, exist_ok=True)
    for c in fleet:
        spec = JobSpec(
            name=f"fl-client-{c.cid}",
            command=f"python -m repro.worker --client-id {c.cid}",
            gpus_per_node=1 if c.profile.compute_tflops > 4 else 0,
            mem_gb=int(c.profile.memory_gb), site=c.site,
            preemptible=c.profile.spot)
        h = hy.submit(spec)
        ext = "sbatch" if c.site == "hpc" else "json"
        (out_dir / f"client{c.cid:03d}.{ext}").write_text(h.artifact)
    return len(fleet)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cifar10",
                    choices=["cifar10", "medmnist", "shakespeare"])
    ap.add_argument("--algo", default="fedavg", choices=["fedavg", "fedprox"])
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="sync: barrier rounds; async: FedBuff buffered "
                         "commits (--rounds then counts server commits)")
    ap.add_argument("--exec-backend", default="closed-form",
                    choices=list(BACKEND_NAMES),
                    help="where simulated client time comes from: "
                         "'closed-form' (lognormal straggler model, the fast "
                         "default) or 'scheduler' (dispatch every attempt as "
                         "a job through the SLURM+K8s hybrid adapter: queue "
                         "waits, elastic HPC->cloud overflow, and spot "
                         "preemptions from the K8s adapter's event stream)")
    ap.add_argument("--hpc-nodes", type=int, default=0,
                    help="scheduler backend: SLURM partition size "
                         "(0 = one node per HPC client)")
    ap.add_argument("--cloud-nodes", type=int, default=0,
                    help="scheduler backend: K8s autoscale ceiling "
                         "(0 = one node per cloud client)")
    ap.add_argument("--spot-preempt-per-min", type=float, default=0.0,
                    help="scheduler backend: per-minute spot reclaim rate "
                         "for preemptible pods (replaces the injector's "
                         "--spot-preempt-prob draw)")
    ap.add_argument("--buffer-k", type=int, default=8,
                    help="async: commit every K buffered updates")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "legacy", "batched", "window"],
                    help="async event engine: 'legacy' processes one event "
                         "at a time; 'batched' defers client training into "
                         "vmap chunks and batches dispatch; 'window' "
                         "additionally blocks every RNG/key draw per commit "
                         "window, keeps pending arrivals in numpy structured "
                         "arrays and performs ONE host sync per window.  All "
                         "three are bit-identical on flat fleets "
                         "(tests/test_megafleet_equivalence.py).  'auto' "
                         "(default) picks by fleet size: per-event dispatch "
                         "is faster below ~%d clients, the window engine "
                         "above (crossover measured in artifacts/bench/"
                         "table_megafleet.json: legacy 3.1 vs batched 3.9 "
                         "wall_per_sim_s at 100 clients, 11x the other way "
                         "at 1k+)" % AUTO_ENGINE_THRESHOLD)
    ap.add_argument("--train-chunk", type=int, default=32,
                    help="batched/window engines: max vmap lanes per "
                         "deferred training chunk")
    ap.add_argument("--event-window", type=int, default=256,
                    help="window engine: events per blocked RNG/key draw "
                         "(and per scheduler GC window)")
    ap.add_argument("--commit-chunk", type=int, default=0,
                    help="async: accumulate the commit buffer this many "
                         "slots at a time instead of stacking all K (0 = "
                         "single-shot; chunked commits agree to ~1e-5, not "
                         "bitwise — float summation order changes)")
    ap.add_argument("--staleness-exp", type=_staleness_exp, default=0.5,
                    help="async: staleness discount 1/(1+s)^a — a float, or "
                         "'adaptive' for the online FedAsync-style alpha "
                         "tuned from the observed staleness distribution")
    ap.add_argument("--secure-agg", action="store_true",
                    help="commit-keyed pairwise masking (Bonawitz-style "
                         "secure aggregation): the server only sees masked "
                         "updates whose masks cancel within each round/"
                         "commit; works in BOTH --mode sync and async")
    ap.add_argument("--facilities", type=int, default=0,
                    help="two-tier federation: split the fleet into N "
                         "facilities, each running --mode locally over its "
                         "own backend, with a tier-2 server federating "
                         "facility deltas over WAN (dcn) links; --rounds "
                         "then counts tier-2 commits/epochs (0 = flat)")
    ap.add_argument("--facility-backend", default="",
                    choices=[""] + list(BACKEND_NAMES),
                    help="execution backend each facility runs on "
                         "(default: inherit --exec-backend)")
    ap.add_argument("--inter-facility-mode", default="sync",
                    choices=["sync", "async"],
                    help="tier-2 regime: 'sync' barriers on every facility "
                         "per epoch; 'async' commits facility deltas as "
                         "they arrive, staleness-discounted")
    ap.add_argument("--local-rounds", type=int, default=2,
                    help="tier-1 rounds/commits one facility runs per "
                         "tier-2 epoch")
    ap.add_argument("--inter-buffer", type=int, default=1,
                    help="async inter-facility mode: tier-2 commit every "
                         "K facility deltas")
    ap.add_argument("--wan-bw", type=float, default=6.25,
                    help="inter-facility WAN bandwidth, GB/s (dcn class)")
    ap.add_argument("--wan-latency", type=float, default=1e-3,
                    help="inter-facility WAN latency, seconds")
    ap.add_argument("--wan-jitter", type=float, default=0.0,
                    help="exponential jitter tail added per WAN transfer, "
                         "seconds (0 = deterministic)")
    ap.add_argument("--max-staleness", type=int, default=20)
    ap.add_argument("--commit-timeout", type=float, default=0.0,
                    help="async: commit a partial buffer after T sim-seconds")
    ap.add_argument("--max-concurrency", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients-pool", type=int, default=60)
    ap.add_argument("--clients-per-round", type=int, default=20)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--mu", type=float, default=0.02)
    ap.add_argument("--quantize-bits", type=int, default=0)
    ap.add_argument("--topk-frac", type=float, default=0.0)
    ap.add_argument("--fed-dropout", type=float, default=0.0)
    ap.add_argument("--use-fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="fused Pallas commit path (compress+mask+accumulate "
                         "in one pass; interpret mode on CPU). --no-use-fused "
                         "forces the unfused jnp stages")
    ap.add_argument("--stochastic-rounding",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="stochastic rounding for quantization "
                         "(--no-stochastic-rounding selects deterministic "
                         "round-to-nearest, the fully-fusable mode)")
    ap.add_argument("--fastest-k", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=0.0)
    ap.add_argument("--dropout-prob", type=float, default=0.0)
    ap.add_argument("--spot-preempt-prob", type=float, default=0.0)
    ap.add_argument("--partition-prob", type=float, default=0.0)
    ap.add_argument("--recovery-policy", default="restart",
                    choices=["restart", "resume", "discard", "adaptive"],
                    help="async: what a preempted/partitioned client does "
                         "with its interrupted attempt (paper §5.4); "
                         "'adaptive' picks per fault from observed "
                         "staleness + remaining work")
    ap.add_argument("--recovery-overhead-s", type=float, default=0.0)
    ap.add_argument("--server-opt", default="fedavg",
                    choices=["fedavg", "fedadam", "fedyogi"])
    ap.add_argument("--selection", default="adaptive",
                    choices=["adaptive", "random"])
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=10,
                    help="sync: rounds between snapshots; async: commits")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in "
                         "--checkpoint-dir (async resumes bit-identically: "
                         "event heap, buffer and RNG streams are restored)")
    ap.add_argument("--render-jobs", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    fed, model, params, eval_fn = build_task(args.dataset, args.clients_pool,
                                             args.seed)
    n_hpc = args.clients_pool // 2
    n_cloud = args.clients_pool - n_hpc

    def build_backend():
        if args.exec_backend != "scheduler":
            return make_backend("closed-form")
        spot_rate = args.spot_preempt_per_min
        if args.spot_preempt_prob and not spot_rate:
            # under the scheduler backend spot preemptions originate from
            # the K8s adapter's reclaim events, not an injector draw — map
            # the per-ATTEMPT Bernoulli probability onto the equivalent
            # per-minute exponential rate at this fleet's mean attempt time
            mean_s = expected_attempt_s(
                fleet, 3e12, payload_bytes(params, fl.compression),
                StragglerPolicy())
            spot_rate = equivalent_preempt_rate_per_min(
                args.spot_preempt_prob, mean_s)
            print(f"scheduler backend: mapped --spot-preempt-prob "
                  f"{args.spot_preempt_prob:g}/attempt onto "
                  f"{spot_rate:.4f} reclaims/min "
                  f"(mean attempt {mean_s:.1f}s)")
        elif args.spot_preempt_prob:
            print("warning: --spot-preempt-per-min overrides the "
                  "--spot-preempt-prob mapping under --exec-backend "
                  "scheduler")
        cloud = args.cloud_nodes or n_cloud
        return make_backend(
            "scheduler",
            slurm=SlurmAdapter(total_nodes=args.hpc_nodes or n_hpc,
                               seed=args.seed),
            k8s=K8sAdapter(initial_nodes=max(1, cloud // 2), max_nodes=cloud,
                           preempt_prob_per_min=spot_rate,
                           seed=args.seed + 1))
    fl = FLConfig(
        mode=args.mode,
        num_clients=args.clients_per_round, local_steps=args.local_steps,
        client_lr=args.lr, fedprox_mu=args.mu if args.algo == "fedprox" else 0.0,
        secure_agg=args.secure_agg,
        compression=CompressionConfig(quantize_bits=args.quantize_bits,
                                      topk_frac=args.topk_frac,
                                      dropout_frac=args.fed_dropout,
                                      stochastic_rounding=args.stochastic_rounding,
                                      use_fused=args.use_fused))
    fleet = make_hybrid_fleet(n_hpc, n_cloud, seed=args.seed,
                              data_sizes=[fed.client_size(c)
                                          for c in range(fed.num_clients)])
    if args.render_jobs:
        n = render_jobs(fleet, Path(args.render_jobs))
        print(f"rendered {n} scheduler artifacts -> {args.render_jobs}")
    faults = FaultConfig(dropout_prob=args.dropout_prob,
                         spot_preempt_prob=args.spot_preempt_prob,
                         partition_prob=args.partition_prob,
                         recovery_policy=args.recovery_policy,
                         recovery_overhead_s=args.recovery_overhead_s)
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume requires --checkpoint-dir")
    if args.facilities:
        fac_backend = args.facility_backend or args.exec_backend
        subs, _ = split_fleet(fleet, args.facilities)

        def backend_factory(f):
            if fac_backend != "scheduler":
                return make_backend("closed-form")
            n_h = sum(c.site == "hpc" for c in subs[f])
            n_c = max(1, sum(c.site == "cloud" for c in subs[f]))
            return make_backend(
                "scheduler",
                slurm=SlurmAdapter(total_nodes=max(1, args.hpc_nodes or n_h),
                                   seed=args.seed + 10 * f),
                k8s=K8sAdapter(initial_nodes=max(1, n_c // 2), max_nodes=n_c,
                               preempt_prob_per_min=args.spot_preempt_per_min,
                               seed=args.seed + 10 * f + 1))

        local_async = AsyncConfig(
            buffer_size=args.buffer_k, staleness_exponent=args.staleness_exp,
            max_staleness=args.max_staleness,
            commit_timeout_s=args.commit_timeout,
            max_concurrency=args.max_concurrency,
            commit_chunk=args.commit_chunk)
        facs = make_facilities(
            args.facilities, fleet, fed, model.loss_fn, fl,
            local_mode=args.mode, async_cfg=local_async,
            local_rounds=args.local_rounds, backend_factory=backend_factory,
            seed=args.seed,
            orch_kw=dict(selection_name=args.selection,
                         straggler=StragglerPolicy(), faults=faults,
                         batch_size=args.batch_size,
                         flops_per_client_round=3e12))
        wan = WANTopology(
            default=LinkClass("dcn", args.wan_bw, args.wan_latency),
            jitter_s=args.wan_jitter)
        mgr = (AsyncCheckpointManager(args.checkpoint_dir)
               if args.checkpoint_dir else None)
        hier = HierarchicalOrchestrator(
            facs, fl, inter_mode=args.inter_facility_mode,
            async_cfg=AsyncConfig(buffer_size=args.inter_buffer,
                                  staleness_exponent=args.staleness_exp
                                  if args.staleness_exp != "adaptive"
                                  else 0.5,
                                  max_staleness=args.max_staleness),
            wan=wan, server_opt_name=args.server_opt, eval_fn=eval_fn,
            eval_every=1, checkpoint_mgr=mgr,
            checkpoint_every=args.checkpoint_every, seed=args.seed)
        server_state = None
        if args.resume and mgr.latest_round() is not None:
            params, server_state = mgr.restore_hier(hier, params)
            print(f"resumed hierarchical run at commit {hier.version} "
                  f"(sim t={hier.clock:.1f}s, {len(hier._events)} facility "
                  f"deltas in flight, {len(hier._buffer)} buffered)")
        params, _ = hier.run(params, args.rounds, server_state=server_state,
                             verbose=True)
        summary = {
            "dataset": args.dataset, "algo": args.algo, "mode": "hier",
            "local_mode": args.mode,
            "inter_facility_mode": args.inter_facility_mode,
            "facilities": args.facilities,
            "local_rounds": args.local_rounds,
            "exec_backend": fac_backend,
            "secure_agg": args.secure_agg,
            "commits": hier.version,
            "dropped_stale": hier.dropped_stale,
            "final_eval": hier.logs[-1].eval_metric if hier.logs else None,
            "virtual_time_s": hier.clock,
            "inter_facility_bytes": hier.inter_facility_bytes,
            "total_bytes": hier.total_bytes(),
            "facility_clocks": [f.clock for f in facs],
        }
    elif args.mode == "async":
        if args.deadline_s or args.fastest_k:
            print("warning: --deadline-s/--fastest-k are barrier-round "
                  "mitigations; the async regime ignores them (staleness "
                  "discounting replaces them)")
        mgr = (AsyncCheckpointManager(args.checkpoint_dir)
               if args.checkpoint_dir else None)
        engine = resolve_engine(args.engine, fleet)
        if args.engine == "auto":
            print(f"--engine auto: {len(fleet)} clients -> {engine} "
                  f"(crossover {AUTO_ENGINE_THRESHOLD})")
        orch_cls = {"legacy": AsyncOrchestrator,
                    "batched": BatchedAsyncOrchestrator,
                    "window": EventWindowOrchestrator}[engine]
        engine_kw = ({} if engine == "legacy"
                     else {"train_chunk": args.train_chunk})
        if engine == "window":
            engine_kw["window"] = args.event_window
        orch = orch_cls(
            fleet=fleet, fed_data=fed, loss_fn=model.loss_fn, fl=fl,
            async_cfg=AsyncConfig(buffer_size=args.buffer_k,
                                  staleness_exponent=args.staleness_exp,
                                  max_staleness=args.max_staleness,
                                  commit_timeout_s=args.commit_timeout,
                                  max_concurrency=args.max_concurrency,
                                  commit_chunk=args.commit_chunk),
            server_opt_name=args.server_opt, selection_name=args.selection,
            straggler=StragglerPolicy(), faults=faults,
            batch_size=args.batch_size, flops_per_client_round=3e12,
            eval_fn=eval_fn, eval_every=10, checkpoint_mgr=mgr,
            checkpoint_every=args.checkpoint_every,
            backend=build_backend(), seed=args.seed, **engine_kw)
        server_state = None
        if args.resume and mgr.latest_round() is not None:
            params, server_state = mgr.restore_async(orch, params)
            print(f"resumed async run at commit {orch.version} "
                  f"(sim t={orch.clock:.1f}s, {len(orch._inflight)} clients "
                  f"in flight, {len(orch._buffer)} updates buffered)")
        params, _ = orch.run(params, args.rounds, server_state=server_state,
                             verbose=True)
        summary = {
            "dataset": args.dataset, "algo": args.algo, "mode": "async",
            "exec_backend": args.exec_backend, "engine": engine,
            "secure_agg": args.secure_agg,
            "mask_overhead_bytes": sum(l.mask_overhead_bytes
                                       for l in orch.logs),
            "commits": orch.version,
            "updates_applied": orch.updates_applied,
            "dropped_stale": orch.dropped_stale,
            "recovered_updates": orch.recovered_updates,
            "lost_to_faults": orch.lost_to_faults,
            "final_eval": orch.logs[-1].eval_metric if orch.logs else None,
            "virtual_time_s": orch.clock,
            "updates_per_sim_s": orch.updates_per_sim_second,
            "mean_queue_wait_s": (float(np.mean([l.queue_wait_s
                                                 for l in orch.logs]))
                                  if orch.logs else 0.0),
            "overflow_updates": sum(l.n_overflow for l in orch.logs),
            "recovery_actions": sum(len(l.recovery_actions)
                                    for l in orch.logs),
        }
    else:
        mgr = (CheckpointManager(args.checkpoint_dir)
               if args.checkpoint_dir else None)
        orch = Orchestrator(
            fleet=fleet, fed_data=fed, loss_fn=model.loss_fn, fl=fl,
            server_opt_name=args.server_opt, selection_name=args.selection,
            straggler=StragglerPolicy(deadline_s=args.deadline_s,
                                      fastest_k=args.fastest_k),
            faults=faults,
            batch_size=args.batch_size, flops_per_client_round=3e12,
            eval_fn=eval_fn, eval_every=10, checkpoint_mgr=mgr,
            checkpoint_every=args.checkpoint_every,
            backend=build_backend(), seed=args.seed)
        server_state, start_round = None, 0
        if args.resume and mgr.latest_round() is not None:
            server_state = orch.init_server_state(params)
            params, server_state, meta = mgr.restore(params, server_state)
            start_round = meta["round"] + 1
            orch.virtual_clock = meta.get("clock", 0.0)
            if meta.get("exec_backend", "closed-form") != args.exec_backend:
                raise SystemExit(
                    f"checkpoint was written under --exec-backend "
                    f"{meta.get('exec_backend', 'closed-form')}; resume "
                    f"with the same backend")
            if meta.get("backend_state"):
                orch.backend.set_state(meta["backend_state"])
            print(f"resumed sync run at round {start_round} "
                  f"(sim t={orch.virtual_clock:.1f}s)")
        params, _ = orch.run(params, args.rounds, server_state=server_state,
                             start_round=start_round, verbose=True)
        summary = {
            "dataset": args.dataset, "algo": args.algo, "mode": "sync",
            "exec_backend": args.exec_backend,
            "secure_agg": args.secure_agg,
            "rounds": args.rounds,
            "final_eval": orch.logs[-1].eval_metric if orch.logs else None,
            "virtual_time_s": orch.virtual_clock,
            "mean_bytes_per_client_round":
                orch.comm.mean_bytes_per_client_round(),
            "mean_queue_wait_s": (float(np.mean([l.mean_queue_wait_s
                                                 for l in orch.logs]))
                                  if orch.logs else 0.0),
            "overflow_clients": sum(l.n_overflow for l in orch.logs),
            "preempted_clients": sum(l.n_preempted for l in orch.logs),
        }
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
