import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import (jax locks the device
# count at first init).  This module is the ONLY place the 512-placeholder-
# device configuration exists; tests/benchmarks see the single real CPU.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from repro.core import CompressionConfig, FLConfig, build_fl_round_step  # noqa: E402
from repro.launch import specs as sp  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model, sharding as sh  # noqa: E402
from repro.models.common import logical_to_pspec_tree  # noqa: E402
from repro.optim import get_client_optimizer, get_server_optimizer  # noqa: E402

# Archs small enough to host parallel client replicas (true hierarchical FL);
# the rest time-multiplex clients sequentially (DESIGN.md §2).
PARALLEL_ARCHS = {"xlstm-125m", "gemma-2b", "granite-3-2b", "musicgen-medium",
                  "starcoder2-7b"}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
               "u16": 2, "c64": 8, "c128": 16}


# ---------------------------------------------------------------------------
# HLO text analysis: collective bytes with while-loop trip-count multipliers
# ---------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)[^{]*\([^)]*\)\s*->", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\([^)]*\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")


def split_computations(hlo: str) -> dict[str, str]:
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        if (line.startswith("ENTRY") or
                (not line.startswith(" ") and "{" in line and "->" in line
                 and stripped.startswith("%"))):
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            cur_name = ("__entry__" if line.startswith("ENTRY")
                        else (m.group(1) if m else stripped[:40]))
            cur_lines = [line]
        elif cur_name:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def trip_count(cond_text: str) -> int:
    """Canonical XLA while-cond: compare(ind_var, constant(N)) — take the
    largest integer constant as the trip count (conservative upper bound)."""
    consts = [int(c) for c in _CONST_CMP_RE.findall(cond_text)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                             r"(?:T\(([\d,]+)\))?")


def crosses_pods(line: str, pod_stride: int) -> bool:
    """True if the collective's replica groups span devices >= pod_stride
    apart (i.e. traffic crosses the pod/DCN boundary)."""
    m = _GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x]
        return bool(ids) and max(ids) - min(ids) >= pod_stride
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota groups [G, S]<=[dims](T(perm)): group = S consecutive entries
        # of the (transposed) iota.  The group spans pods iff the minor
        # (fastest-varying) S elements cover an index jump >= pod_stride.
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        import numpy as _np
        ids = _np.arange(int(_np.prod(dims))).reshape(dims).transpose(perm)
        ids = ids.reshape(g, s)
        return bool((ids.max(1) - ids.min(1) >= pod_stride).any())
    return False


def collective_bytes(hlo: str, pod_stride: int = 256) -> dict:
    """Per-collective-kind bytes, execution-weighted by while trip counts.
    Each kind also gets a '<kind>/cross_pod' entry for traffic whose replica
    groups span the pod boundary (DCN, not ICI)."""
    comps = split_computations(hlo)

    def comp_cost(name: str, seen) -> dict:
        if name in seen:
            return {}
        seen = seen | {name}
        text = comps.get(name, "")
        out: dict[str, float] = {}
        for line in text.splitlines():
            s = line.strip()
            for kind in COLLECTIVE_OPS:
                if f" {kind}(" in s or s.startswith(f"{kind}("):
                    # output type(s) appear between '=' and the op name
                    lhs = s.split(f"{kind}(")[0]
                    eq = lhs.find("=")
                    b = shape_bytes(lhs[eq + 1:])
                    out[kind] = out.get(kind, 0) + b
                    if crosses_pods(s, pod_stride):
                        key = kind + "/cross_pod"
                        out[key] = out.get(key, 0) + b
                    break
            m = _WHILE_RE.search(s)
            if m:
                cond, body = m.group(1), m.group(2)
                tc = trip_count(comps.get(cond, ""))
                for k, v in comp_cost(body, seen).items():
                    out[k] = out.get(k, 0) + tc * v
        return out

    entry = "__entry__" if "__entry__" in comps else next(iter(comps), "")
    res = comp_cost(entry, frozenset())
    return {k: int(v) for k, v in res.items()}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def repl(mesh):
    return NamedSharding(mesh, P())


def build_train(model, cfg, shape, mesh, multi_pod, clients, local_steps):
    parallel = cfg.name in PARALLEL_ARCHS
    n_pods = 2 if multi_pod else 1
    C = clients or ((32 if multi_pod else 16) if parallel else 4)
    H = local_steps
    if parallel:
        exec_mode = "parallel"
    elif multi_pod:
        exec_mode = "pod_sequential"   # clients pinned to pods (sites)
    else:
        exec_mode = "sequential"
    fl_cfg = FLConfig(
        num_clients=C, local_steps=H, client_lr=0.01, fedprox_mu=0.01,
        aggregation="fedavg",
        client_exec=exec_mode,
        compression=CompressionConfig(quantize_bits=8),
        hierarchical=parallel and multi_pod,
        accum_dtype="bfloat16")
    bspecs, blog_par, blog_seq = sp.train_client_batch_specs(cfg, shape, C, H)
    blog = blog_par if parallel else blog_seq
    if exec_mode == "pod_sequential":
        # client dim over `pod`, per-client batch over `data` only
        def podify(logical):
            e = list(logical)
            e[0] = sh.POD
            e[2] = sh.DATA
            return tuple(e)
        blog = jax.tree.map(podify, blog,
                            is_leaf=lambda x: isinstance(x, tuple))
    param_sds = model.param_specs()
    param_sh = sp.sanitize_specs(param_sds, model.logical_specs, mesh)
    # param-sharding constraints only in (non-vmapped) sequential mode;
    # vmapped modes declare the mapped dim's mesh axes via spmd_axis_name
    # instead (EXPERIMENTS.md §Perf iteration 4).
    if exec_mode == "parallel":
        spmd_axes = ("pod", "data") if multi_pod else "data"
    elif exec_mode == "pod_sequential":
        spmd_axes = "pod"
    else:
        spmd_axes = None
    step = build_fl_round_step(
        model.loss_fn, get_client_optimizer("sgd"),
        get_server_optimizer("fedavg"), fl_cfg, n_pods=n_pods,
        param_shardings=param_sh if exec_mode == "sequential" else None,
        client_spmd_axes=spmd_axes)
    batch_sh = sp.sanitize_specs(bspecs, blog, mesh)
    vec = jax.ShapeDtypeStruct((C,), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    args = (param_sds, (), bspecs, vec, vec, key)
    in_sh = (param_sh, (), batch_sh, repl(mesh), repl(mesh), repl(mesh))
    out_sh = (param_sh, (), None)
    meta = {"clients": C, "local_steps": H,
            "client_exec": fl_cfg.client_exec,
            "hierarchical": fl_cfg.hierarchical}
    return step, args, in_sh, out_sh, meta


def build_prefill(model, cfg, shape, mesh):
    bspecs, blog = sp.prefill_batch_specs(cfg, shape)
    param_sds = model.param_specs()
    param_sh = sp.sanitize_specs(param_sds, model.logical_specs, mesh)
    batch_sh = sp.sanitize_specs(bspecs, blog, mesh)
    state_sh = sp.sanitize_specs(
        model.decode_state_specs(shape.global_batch, shape.seq_len),
        model.state_logical_specs(shape.global_batch, shape.seq_len), mesh)

    def step(params, batch):
        return model.prefill(params, batch, s_max=shape.seq_len)

    return (step, (param_sds, bspecs), (param_sh, batch_sh),
            (None, state_sh), {})


def build_decode(model, cfg, shape, mesh):
    (token, tok_log, state, state_log, patches,
     patches_log) = sp.decode_inputs_specs(cfg, shape, model)
    param_sds = model.param_specs()
    param_sh = sp.sanitize_specs(param_sds, model.logical_specs, mesh)
    tok_sh = sp.sanitize_specs(token, tok_log, mesh)
    state_sh = sp.sanitize_specs(state, state_log, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    if patches is not None:
        patch_sh = sp.sanitize_specs(patches, patches_log, mesh)

        def step(params, st, tok, p, patch):
            return model.decode_step(params, st, tok, p, patch)

        return (step, (param_sds, state, token, pos, patches),
                (param_sh, state_sh, tok_sh, repl(mesh), patch_sh),
                (None, state_sh), {})

    def step(params, st, tok, p):
        return model.decode_step(params, st, tok, p)

    return (step, (param_sds, state, token, pos),
            (param_sh, state_sh, tok_sh, repl(mesh)),
            (None, state_sh), {})


# ---------------------------------------------------------------------------

def should_skip(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return ("full-attention arch without sliding-window/SSM variant; "
                "long_500k requires a sub-quadratic decode path "
                "(DESIGN.md long_500k skips)")
    return None


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
            groups: int = 0, clients: int = 0, local_steps: int = 1,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}" + (f"__G{groups}" if groups else "")
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "groups_override": groups, "tag": tag}

    skip = should_skip(cfg, shape)
    if skip:
        result["skipped"] = skip
        _write(out_dir, tag, result)
        return result

    if groups:
        from repro.models.transformer import block_pattern
        period = len(block_pattern(cfg))
        cfg = cfg.replace(n_layers=groups * period)

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    result["n_devices"] = int(np.prod(list(mesh.shape.values())))

    with sh.use_mesh(mesh):
        if shape.kind == "train":
            step, args, in_sh, out_sh, meta = build_train(
                model, cfg, shape, mesh, multi_pod, clients, local_steps)
        elif shape.kind == "prefill":
            step, args, in_sh, out_sh, meta = build_prefill(model, cfg, shape, mesh)
        else:
            step, args, in_sh, out_sh, meta = build_decode(model, cfg, shape, mesh)
        result.update(meta)

        t0 = time.time()
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
        result["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t0, 2)

        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        result["cost_analysis"] = {
            "flops": float(ca.get("flops", -1)) if ca else -1,
            "bytes_accessed": float(ca.get("bytes accessed", -1)) if ca else -1,
            "note": "XLA HloCostAnalysis counts while bodies once; see "
                    "benchmarks/costmodel.py for trip-count-corrected terms",
        }
        try:
            ma = compiled.memory_analysis()
            result["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes") if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not implement it
            result["memory_analysis"] = {"error": str(e)}

        hlo = compiled.as_text()
        if os.environ.get("REPRO_DUMP_HLO"):
            (out_dir / f"{tag}.hlo.txt").parent.mkdir(parents=True,
                                                      exist_ok=True)
            (out_dir / f"{tag}.hlo.txt").write_text(hlo)
        result["collective_bytes"] = collective_bytes(hlo)
        result["collective_ops_static"] = {
            k: hlo.count(f" {k}(") for k in COLLECTIVE_OPS}
        result["hlo_chars"] = len(hlo)

    _write(out_dir, tag, result)
    if verbose:
        cb = sum(result["collective_bytes"].values())
        print(f"[dryrun] {tag}: lower {result['lower_s']}s "
              f"compile {result['compile_s']}s "
              f"collectives {cb/1e9:.2f} GB "
              f"temp {result['memory_analysis'].get('temp_size_in_bytes', 0)/1e9:.2f} GB")
    return result


def _write(out_dir: Path, tag: str, result: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    choices=list(INPUT_SHAPES) + ["all"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--groups", type=int, default=0,
                    help="override n_layers = groups*period (cost decomposition)")
    ap.add_argument("--clients", type=int, default=0)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, Path(args.out), groups=args.groups,
                            clients=args.clients, local_steps=args.local_steps)
                except Exception as e:
                    tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                    print(f"[dryrun] FAILED {tag}: {type(e).__name__}: {e}")
                    _write(Path(args.out), tag,
                           {"arch": arch, "shape": shape,
                            "mesh": "multi" if mp else "single", "tag": tag,
                            "error": f"{type(e).__name__}: {str(e)[:2000]}"})
                jax.clear_caches()


if __name__ == "__main__":
    main()
