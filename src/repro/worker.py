"""Federated client worker — the process a scheduler job actually launches
(`python -m repro.worker`, as rendered into the sbatch scripts / pod
manifests by the scheduler adapters).

File-based transport: the orchestrator drops `global_round_NNN.bin` into
--workdir, the worker trains locally on its private shard and writes
`update_NNN_client_CC.bin` back.  This is the deployment-shaped
counterpart of the in-process round step; `--once` runs a single round and
exits (spot-instance friendly)."""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.core import FLConfig
from repro.core.round import build_local_train
from repro.data import FederatedDataset, cifar10_like, partition_by_class
from repro.models.cnn import CIFAR_CNN, CNN
from repro.optim import get_client_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--client-id", type=int, required=True)
    ap.add_argument("--workdir", default="artifacts/worker")
    ap.add_argument("--n-clients", type=int, default=20)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--mu", type=float, default=0.0)
    ap.add_argument("--once", action="store_true")
    ap.add_argument("--poll-s", type=float, default=1.0)
    ap.add_argument("--timeout-s", type=float, default=30.0)
    args = ap.parse_args()

    wd = Path(args.workdir)
    wd.mkdir(parents=True, exist_ok=True)

    # this client's private shard (never leaves the process)
    ds = cifar10_like(n=4000)
    parts = partition_by_class(ds.y, args.n_clients, 2)
    fed = FederatedDataset(ds, parts)
    model = CNN(CIFAR_CNN)
    params_like = model.init(jax.random.PRNGKey(0))

    fl = FLConfig(num_clients=1, local_steps=args.local_steps,
                  client_lr=args.lr, fedprox_mu=args.mu)
    local_train = jax.jit(build_local_train(
        model.loss_fn, get_client_optimizer("sgd"), fl))

    done = set()
    deadline = time.time() + args.timeout_s
    while time.time() < deadline:
        rounds = sorted(wd.glob("global_round_*.bin"))
        todo = [p for p in rounds if p.name not in done]
        if not todo:
            time.sleep(args.poll_s)
            continue
        gpath = todo[-1]
        rnd = int(gpath.stem.split("_")[-1])
        params = load_pytree(gpath, params_like)
        batch = fed.sample_round([args.client_id], args.local_steps,
                                 args.batch_size)
        batch = jax.tree.map(lambda x: jnp.asarray(x[0]), batch)
        delta, loss = local_train(params, batch,
                                  jax.random.PRNGKey(rnd * 1000 + args.client_id))
        out = wd / f"update_{rnd:04d}_client_{args.client_id:03d}.bin"
        save_pytree(out, jax.tree.map(np.asarray, delta))
        (wd / f"update_{rnd:04d}_client_{args.client_id:03d}.json").write_text(
            json.dumps({"loss": float(loss),
                        "data_size": fed.client_size(args.client_id)}))
        print(f"worker {args.client_id}: round {rnd} loss {float(loss):.4f} "
              f"-> {out.name}")
        done.add(gpath.name)
        if args.once:
            break


if __name__ == "__main__":
    main()
