"""Mamba (S6) block: selective state-space model with chunked scan.

The training path uses a *chunked* selective scan (lax.scan over chunks,
associative scan inside a chunk) so the [B, S, d_inner, d_state] tensor is
never materialised — only [B, chunk, d_inner, d_state].  The inner chunk is
also available as a Pallas kernel (repro.kernels.selective_scan); this module
calls the pure-jnp path by default and the kernel when
``use_kernel=True`` (tests assert they match).

d_inner is sharded over `model`; the scan is sequential over S only, so the
recurrence needs no cross-shard communication (recurrent-scan sharding).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig
from repro.models import sharding as sh


def dt_rank(d_model: int, cfg: MambaConfig) -> int:
    return cfg.dt_rank or math.ceil(d_model / 16)


def init_mamba(builder, path, d_model: int, cfg: MambaConfig, n_groups: int):
    di = cfg.expand * d_model
    R = dt_rank(d_model, cfg)
    N = cfg.d_state
    g = (n_groups,) if n_groups else ()
    pre = (None,) if n_groups else ()
    add = builder.add
    add({}, path + ["in_proj"], g + (d_model, 2 * di), pre + (sh.DATA, sh.MODEL))
    add({}, path + ["conv_w"], g + (cfg.d_conv, di), pre + (None, sh.MODEL))
    add({}, path + ["conv_b"], g + (di,), pre + (sh.MODEL,), init="zeros")
    add({}, path + ["x_proj"], g + (di, R + 2 * N), pre + (sh.MODEL, None))
    add({}, path + ["dt_proj"], g + (R, di), pre + (None, sh.MODEL))
    add({}, path + ["dt_bias"], g + (di,), pre + (sh.MODEL,),
        init=lambda k, s: jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k, s, jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))))
    add({}, path + ["A_log"], g + (di, N), pre + (sh.MODEL, None),
        init=lambda k, s: jnp.broadcast_to(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), s))
    add({}, path + ["D"], g + (di,), pre + (sh.MODEL,), init="ones")
    add({}, path + ["out_proj"], g + (di, d_model), pre + (sh.MODEL, sh.DATA))


def _ssm_coeffs(x, p, cfg: MambaConfig):
    """x [B, L, di] -> decay a [B,L,di,N], drive b [B,L,di,N], C [B,L,N]."""
    N = cfg.d_state
    R = p["dt_proj"].shape[0]
    proj = x @ p["x_proj"]                                  # [B,L,R+2N]
    dt_in, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])      # [B,L,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # [di, N]
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)      # [B,L,di,N]
    b = (dt[..., None] * Bc[..., None, :]).astype(jnp.float32) * x[..., None].astype(jnp.float32)
    return a, b, Cc


def _chunk_scan(a, b, h0):
    """Associative scan of h_t = a_t * h_{t-1} + b_t within one chunk.
    a,b [B,L,di,N]; h0 [B,di,N].  Returns (h all steps, h_last)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = aa * h0[:, None] + bb
    return h, h[:, -1]


def selective_scan_chunked(a, b, C, h0, chunk: int, use_kernel: bool = False):
    """Full-sequence selective scan via chunks.  a,b [B,S,di,N]; C [B,S,N].
    Returns y [B,S,di] and final state [B,di,N]."""
    B, S, di, N = a.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def one_chunk(ac, bc, Cc, h):
        if use_kernel:
            from repro.kernels import ops as kops
            hs, h_last = kops.selective_scan_chunk(ac, bc, h)
        else:
            hs, h_last = _chunk_scan(ac, bc, h)
        y = jnp.einsum("bldn,bln->bld", hs, Cc.astype(hs.dtype))
        return y, h_last

    a_c = a[:, :n * chunk].reshape(B, n, chunk, di, N).swapaxes(0, 1)
    b_c = b[:, :n * chunk].reshape(B, n, chunk, di, N).swapaxes(0, 1)
    C_c = C[:, :n * chunk].reshape(B, n, chunk, N).swapaxes(0, 1)

    def body(h, xs):
        y, h_last = one_chunk(*xs, h)
        return h_last, y

    h_last, ys = jax.lax.scan(body, h0, (a_c, b_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, n * chunk, di)
    if rem:
        y_r, h_last = one_chunk(a[:, n * chunk:], b[:, n * chunk:],
                                C[:, n * chunk:], h_last)
        y = jnp.concatenate([y, y_r], axis=1)
    return y, h_last


def mamba_apply(p, x, *, cfg: MambaConfig, mode: str = "train", state=None,
                use_kernel: bool = False):
    """x [B,S,D].  mode train/prefill: full scan (prefill also returns state).
    mode decode: x [B,1,D] with state=(conv_state [B,d_conv-1,di], h [B,di,N])."""
    B, S, D = x.shape
    di = cfg.expand * D
    N = cfg.d_state
    xz = x @ p["in_proj"]                                   # [B,S,2di]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = sh.shard(xin, sh.BATCH, None, sh.MODEL)

    if mode in ("train", "prefill"):
        # causal depthwise conv
        pad = jnp.zeros((B, cfg.d_conv - 1, di), xin.dtype)
        xpad = jnp.concatenate([pad, xin], axis=1)
        conv = sum(xpad[:, i:i + S] * p["conv_w"][i] for i in range(cfg.d_conv))
        conv = jax.nn.silu(conv + p["conv_b"])
        a, b, Cc = _ssm_coeffs(conv, p, cfg)
        h0 = jnp.zeros((B, di, N), jnp.float32)
        y, h_last = selective_scan_chunked(a, b, Cc, h0, cfg.chunk, use_kernel)
        y = y.astype(x.dtype) + conv * p["D"]
        out = (jax.nn.silu(z) * y) @ p["out_proj"]
        if mode == "prefill":
            # keep the last d_conv-1 raw (pre-conv) inputs for decode
            new_state = {"conv": xpad[:, -(cfg.d_conv - 1):], "h": h_last}
            return out, new_state
        return out, None

    # decode: single token
    conv_state, h = state["conv"], state["h"]               # [B,dc-1,di], [B,di,N]
    x1 = xin[:, 0]                                          # [B,di]
    window = jnp.concatenate([conv_state, x1[:, None]], axis=1)  # [B,dc,di]
    conv = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)[:, None]                       # [B,1,di]
    a, b, Cc = _ssm_coeffs(conv, p, cfg)
    h_new = a[:, 0] * h + b[:, 0]                           # [B,di,N]
    y = jnp.einsum("bdn,bn->bd", h_new, Cc[:, 0].astype(h_new.dtype))
    y = y.astype(x.dtype)[:, None] + conv * p["D"]
    out = (jax.nn.silu(z) * y) @ p["out_proj"]
    return out, {"conv": window[:, 1:], "h": h_new}
