"""Attention: GQA/MQA/MHA with RoPE, optional sliding window, chunked
(flash-style online-softmax) computation for long sequences, and a
flash-decoding serve path over a sequence-sharded KV cache.

Layouts:
  q        [B, S, H, hd]
  k, v     [B, T, KV, hd]      (KV heads never repeated in memory)
  caches   [B, S_max, KV, hd]  (decode: S_max sharded over `model`)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import sharding as sh

NEG_INF = -1e30


def _group(q, kv_heads):
    B, S, H, hd = q.shape
    return q.reshape(B, S, kv_heads, H // kv_heads, hd)


def _scores_mask(qpos, kpos, causal: bool, window: int):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def attend_full(q, k, v, *, q0: int = 0, k0: int = 0, causal=True, window=0,
                kv_valid=None):
    """Plain (un-chunked) GQA attention on small S.  q0/k0: position offsets."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = _group(q, KV)                                   # [B,S,KV,G,hd]
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k).astype(jnp.float32)
    scores *= hd ** -0.5
    mask = _scores_mask(q0 + jnp.arange(S), k0 + jnp.arange(k.shape[1]), causal, window)
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", p, v)
    return out.reshape(B, S, H, hd)


def attend_chunked(q, k, v, *, causal=True, window=0, q_chunk=1024, kv_chunk=1024):
    """Memory-bounded attention: outer scan over q chunks, inner scan over kv
    chunks with online softmax.  Never materialises [S, S] scores."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = S // q_chunk, T // kv_chunk
    assert nq * q_chunk == S and nk * kv_chunk == T, (S, T, q_chunk, kv_chunk)
    qg = _group(q, KV).reshape(B, nq, q_chunk, KV, G, hd).swapaxes(0, 1)
    ks = k.reshape(B, nk, kv_chunk, KV, hd).swapaxes(0, 1)
    vs = v.reshape(B, nk, kv_chunk, KV, hd).swapaxes(0, 1)
    scale = hd ** -0.5

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx                              # [B,qc,KV,G,hd]
        q0 = iq * q_chunk

        def kv_step(carry, kv_and_idx):
            m, l, acc = carry
            (kc, vc), ik = kv_and_idx                    # [B,kc,KV,hd]
            k0 = ik * kv_chunk
            s = jnp.einsum("bqngd,bknd->bngqk", qi, kc)
            s = s.astype(jnp.float32) * scale            # [B,KV,G,qc,kc]
            msk = _scores_mask(q0 + jnp.arange(q_chunk), k0 + jnp.arange(kv_chunk),
                               causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bknd->bngqd", p.astype(vc.dtype), vc).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, ((ks, vs), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,KV,G,qc,hd]
        return None, out.transpose(0, 3, 1, 2, 4)        # [B,qc,KV,G,hd]

    _, outs = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    out = outs.swapaxes(0, 1).reshape(B, S, H, hd).astype(q.dtype)
    return out


def attend(q, k, v, *, causal=True, window=0, chunk_threshold=2048,
           q_chunk=1024, kv_chunk=1024):
    if q.shape[1] <= chunk_threshold:
        return attend_full(q, k, v, causal=causal, window=window)
    return attend_chunked(q, k, v, causal=causal, window=window,
                          q_chunk=min(q_chunk, q.shape[1]),
                          kv_chunk=min(kv_chunk, k.shape[1]))


# ---------------------------------------------------------------------------
# Decode (one query token against a cache)
# ---------------------------------------------------------------------------

def decode_attend(q1, k_cache, v_cache, pos, *, window=0):
    """q1 [B,H,hd]; caches [B,S,KV,hd]; pos scalar index of the current token
    (caches already contain the current token's k/v at `pos`, or, for ring
    buffers, at pos % S).  Softmax over the cache dim; under a mesh the cache
    S dim is `model`-sharded so this lowers to flash-decoding psum merges."""
    B, S, KV, hd = k_cache.shape
    H = q1.shape[1]
    qg = q1.reshape(B, KV, H // KV, hd)
    s = jnp.einsum("bngd,btnd->bngt", qg, k_cache).astype(jnp.float32)
    s *= hd ** -0.5
    idx = jnp.arange(S)
    if window:
        # ring buffer of size S == window: once full, every slot holds one of
        # the last S tokens (incl. current) and is valid.
        valid = jnp.where(pos + 1 >= S, jnp.ones((S,), jnp.bool_), idx <= pos)
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q1.dtype)
    out = jnp.einsum("bngt,btnd->bngd", p, v_cache)
    return out.reshape(B, H, hd)


def cache_write(cache, new, pos):
    """Write new [B,1,KV,hd] at slot `pos` (caller handles ring modulo)."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), pos, axis=1)


# ---------------------------------------------------------------------------
# Cross attention (VLM): kv from patch embeddings, no mask/rope.
# ---------------------------------------------------------------------------

def cross_attend(q, k, v):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = _group(q, KV)
    s = jnp.einsum("bsngd,btnd->bngst", qg, k).astype(jnp.float32) * hd ** -0.5
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", p, v)
    return out.reshape(B, S, H, hd)
