"""Mixture-of-Experts layer with TPU-native sharding.

Design (DESIGN.md §6):
  * Expert weights at rest: E sharded over `model`, expert d_ff over `data`
    (full 2-D sharding; kimi-k2's 1T params -> ~8 GB/chip).
  * Dispatch is LOCAL per model-shard: every shard routes its data-shard's
    tokens against the full router, then sort-based capacity-gathers only the
    tokens assigned to its E/|model| local experts.  No global [T, E, C]
    one-hot dispatch tensor is ever built (GShard-style dispatch would be
    ~4e13 elements at kimi scale).
  * Train/prefill ("gather_weights"): expert weights are all-gathered over
    `data` per layer (transient ZeRO-3 gather) because tokens are big.
  * Decode ("gather_tokens"): the (tiny) token batch is all-gathered over the
    batch axes instead and weights stay fully sharded.
  * Outputs are psum-combined over `model` (each shard contributes its local
    experts' outputs) — the expert-parallel analogue of TP.

Implemented with shard_map when a mesh is active; the same inner function
runs directly (world size 1) in unit tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map as _shard_map  # jax >= 0.7 (check_vma kwarg)
    def shard_map(f, **kw):
        kw["check_vma"] = kw.pop("check_rep", False)
        return _shard_map(f, **kw)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.models import sharding as sh
from repro.models.common import act_fn


def init_moe(builder, path, d_model: int, cfg: MoEConfig, n_groups: int):
    E, F = cfg.num_experts, cfg.d_expert
    g = (n_groups,) if n_groups else ()
    pre = (None,) if n_groups else ()
    # router is tiny ([D, E]) -> replicated so routing needs no weight gather
    builder.add({}, path + ["router"], g + (d_model, E), pre + (None, None))
    builder.add({}, path + ["w1"], g + (E, d_model, F), pre + (sh.MODEL, None, sh.DATA))
    builder.add({}, path + ["w3"], g + (E, d_model, F), pre + (sh.MODEL, None, sh.DATA))
    builder.add({}, path + ["w2"], g + (E, F, d_model), pre + (sh.MODEL, sh.DATA, None))


def _route(x2d, router, cfg: MoEConfig):
    """x2d [T, D] -> (expert ids [T,K], gate weights [T,K], aux loss)."""
    logits = (x2d.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # [T, E]
    gate, eid = jax.lax.top_k(probs, cfg.top_k)                   # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = cfg.num_experts
    hard = jax.nn.one_hot(eid[:, 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(hard.mean(0) * probs.mean(0))
    return eid, gate.astype(x2d.dtype), aux


def _dispatch_indices(eid, gate, e_lo: int, e_n: int, capacity: int):
    """Sort-based capacity dispatch for local experts [e_lo, e_lo+e_n).

    Returns tok_idx [e_n, C] (into the flat token dim; slot 0 used for
    dropped/empty with gate 0) and gates [e_n, C]."""
    T, K = eid.shape
    flat_e = eid.reshape(-1)                                       # [T*K]
    flat_g = gate.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    local = flat_e - e_lo
    in_range = (local >= 0) & (local < e_n)
    key = jnp.where(in_range, local, e_n)                          # out-of-range last
    order = jnp.argsort(key, stable=True)
    k_sorted = key[order]
    # rank within each expert segment
    seg_start = jnp.searchsorted(k_sorted, jnp.arange(e_n + 1))
    rank = jnp.arange(T * K) - seg_start[jnp.clip(k_sorted, 0, e_n)]
    keep = (k_sorted < e_n) & (rank < capacity)
    e_slot = jnp.where(keep, k_sorted, e_n)                        # drop -> row e_n
    c_slot = jnp.where(keep, rank, 0)
    tok_idx = jnp.zeros((e_n + 1, capacity), jnp.int32).at[e_slot, c_slot].set(
        flat_t[order].astype(jnp.int32), mode="drop")
    gates = jnp.zeros((e_n + 1, capacity), flat_g.dtype).at[e_slot, c_slot].set(
        jnp.where(keep, flat_g[order], 0), mode="drop")
    return tok_idx[:e_n], gates[:e_n]


def _expert_ffn(xs, w1, w3, w2, act: str):
    """xs [E, C, D] through per-expert gated FFN."""
    h1 = jnp.einsum("ecd,edf->ecf", xs, w1)
    if act in ("swiglu", "geglu"):
        inner = act_fn({"swiglu": "silu", "geglu": "gelu"}[act])
        h = inner(h1) * jnp.einsum("ecd,edf->ecf", xs, w3)
    else:
        h = act_fn(act)(h1)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _moe_local(x, router, w1, w3, w2, *, cfg: MoEConfig, act: str,
               model_axis, f_axes, token_axes, mode: str):
    """Per-shard MoE body.  x [B_loc, S, D] (tokens local to this data shard,
    replicated over `model`).  w* local: [E_loc, D, F_loc] etc.

    f_axes:     mesh axes the expert F dim is sharded over at rest.
    token_axes: mesh axes the token batch is sharded over (may be () for
                batch-1 decode)."""
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    T = x2d.shape[0]
    E_loc = w1.shape[0]
    midx = jax.lax.axis_index(model_axis) if model_axis else 0
    e_lo = midx * E_loc

    if mode == "gather_weights":
        # ZeRO-3 style transient gather of the expert F dim.
        if f_axes:
            w1 = jax.lax.all_gather(w1, f_axes, axis=2, tiled=True)
            w3 = jax.lax.all_gather(w3, f_axes, axis=2, tiled=True)
            w2 = jax.lax.all_gather(w2, f_axes, axis=1, tiled=True)
        eid, gate, aux = _route(x2d, router, cfg)
        cap = max(int(T * cfg.top_k * cfg.capacity_factor / cfg.num_experts), 4)
        tok_idx, gates = _dispatch_indices(eid, gate, e_lo, E_loc, cap)
        xs = x2d[tok_idx.reshape(-1)].reshape(E_loc, cap, D)
        ys = _expert_ffn(xs, w1, w3, w2, act)
        out = jnp.zeros_like(x2d).at[tok_idx.reshape(-1)].add(
            (gates[..., None] * ys).reshape(-1, D))
        if model_axis:
            out = jax.lax.psum(out, model_axis)
            aux = jax.lax.pmean(aux, model_axis)
    else:  # gather_tokens (decode): replicate the tiny batch, keep F sharded
        if token_axes:
            x2d = jax.lax.all_gather(x2d, token_axes, axis=0, tiled=True)
        Tg = x2d.shape[0]
        eid, gate, aux = _route(x2d, router, cfg)
        cap = max(int(Tg * cfg.top_k * cfg.capacity_factor / cfg.num_experts), 4)
        tok_idx, gates = _dispatch_indices(eid, gate, e_lo, E_loc, cap)
        xs = x2d[tok_idx.reshape(-1)].reshape(E_loc, cap, D)
        ys = _expert_ffn(xs, w1, w3, w2, act)        # partial over F_loc
        out = jnp.zeros_like(x2d).at[tok_idx.reshape(-1)].add(
            (gates[..., None] * ys).reshape(-1, D))
        if model_axis:
            out = jax.lax.psum(out, model_axis)
        if f_axes:
            out = jax.lax.psum(out, f_axes)          # sum F partials
            aux = jax.lax.pmean(aux, f_axes)
        if token_axes:
            didx = jax.lax.axis_index(token_axes)
            out = jax.lax.dynamic_slice_in_dim(out, didx * T, T, axis=0)
    return out.reshape(B, S, D), aux


def moe_apply(p, x, *, cfg: MoEConfig, act: str, mode: str = "gather_weights"):
    """x [B, S, D]; p has router/w1/w3/w2 (already sliced to this layer)."""
    mesh = sh.get_mesh()
    if mesh is None:
        out, aux = _moe_local(x, p["router"], p["w1"], p["w3"], p["w2"],
                              cfg=cfg, act=act, model_axis=None, f_axes=(),
                              token_axes=(), mode="gather_weights")
        return out, aux

    batch = sh.batch_axes(mesh)
    model_axis = sh.MODEL if sh.MODEL in mesh.axis_names else None
    data_ax = sh.DATA if sh.DATA in mesh.axis_names else None
    # shard the token batch only over axes its size divides by
    tok_axes = []
    rem = x.shape[0]
    for a in batch:
        if rem % mesh.shape[a] == 0:
            tok_axes.append(a)
            rem //= mesh.shape[a]
    tok_axes = tuple(tok_axes)
    x_spec = P(tok_axes if len(tok_axes) != 1 else tok_axes[0], None, None) \
        if tok_axes else P(None, None, None)
    f_axes = (data_ax,) if data_ax else ()
    specs = dict(
        router=P(None, None),
        w1=P(model_axis, None, data_ax),
        w3=P(model_axis, None, data_ax),
        w2=P(model_axis, data_ax, None),
    )
    fn = partial(_moe_local, cfg=cfg, act=act, model_axis=model_axis,
                 f_axes=f_axes,
                 token_axes=tok_axes if mode == "gather_tokens" else (),
                 mode=mode)
    out, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, specs["router"], specs["w1"], specs["w3"], specs["w2"]),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], p["w1"], p["w3"], p["w2"])
    return out, aux
