"""The paper's experiment models for image tasks: a CIFAR-scale CNN and a
MedMNIST-scale classifier (§5.2).  Same (params, batch) -> (loss, aux) API
as the LM zoo so the FL round step is model-agnostic."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import ParamBuilder


@dataclass(frozen=True)
class CNNConfig:
    name: str
    in_shape: tuple          # (H, W, C)
    num_classes: int
    channels: tuple = (32, 64)
    dense: int = 256


CIFAR_CNN = CNNConfig("paper-cifar-cnn", (32, 32, 3), 10)
MEDMNIST_CNN = CNNConfig("paper-medmnist-cnn", (28, 28, 1), 9,
                         channels=(16, 32), dense=128)


class CNN:
    def __init__(self, cfg: CNNConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        pb = ParamBuilder(rng, jnp.float32)
        c_in = cfg.in_shape[-1]
        h, w = cfg.in_shape[:2]
        for i, c_out in enumerate(cfg.channels):
            pb.add({}, [f"conv{i}_w"], (3, 3, c_in, c_out), (None,) * 4,
                   scale=0.1)
            pb.add({}, [f"conv{i}_b"], (c_out,), (None,), init="zeros")
            c_in = c_out
            h, w = h // 2, w // 2
        flat = h * w * c_in
        pb.add({}, ["dense1_w"], (flat, cfg.dense), (None, None))
        pb.add({}, ["dense1_b"], (cfg.dense,), (None,), init="zeros")
        pb.add({}, ["dense2_w"], (cfg.dense, cfg.num_classes), (None, None))
        pb.add({}, ["dense2_b"], (cfg.num_classes,), (None,), init="zeros")
        return pb.params

    def apply(self, params, x):
        for i in range(len(self.cfg.channels)):
            x = jax.lax.conv_general_dilated(
                x, params[f"conv{i}_w"], window_strides=(1, 1),
                padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + params[f"conv{i}_b"])
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["dense1_w"] + params["dense1_b"])
        return x @ params["dense2_w"] + params["dense2_b"]

    def loss_fn(self, params, batch):
        logits = self.apply(params, batch["image"])
        labels = batch["label"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = (lse - picked).mean()
        acc = (logits.argmax(-1) == labels).mean()
        return loss, {"acc": acc}

    def accuracy(self, params, batch):
        logits = self.apply(params, batch["image"])
        return (logits.argmax(-1) == batch["label"]).mean()
