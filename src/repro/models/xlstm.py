"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, true recurrence via lax.scan).

mLSTM uses the chunkwise-parallel form of gated linear attention: within a
chunk the quadratic (decay-weighted) attention is computed directly, across
chunks a matrix state (C [hd, hd], normaliser n [hd], stabiliser m) is
carried — O(S·chunk) instead of O(S^2), recurrent O(1) decode.

sLSTM has hidden-to-gate feedback so it cannot be parallelised over time;
we scan.  Exponential gating is stabilised with the max-state m as in the
paper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
try:
    from jax import shard_map as _shard_map  # jax >= 0.7 (check_vma kwarg)
    def shard_map(f, **kw):
        kw["check_vma"] = kw.pop("check_rep", False)
        return _shard_map(f, **kw)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from repro.configs.base import XLSTMConfig
from repro.models import sharding as sh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(builder, path, d_model: int, n_heads: int, cfg: XLSTMConfig,
               n_groups: int):
    du = int(cfg.proj_factor * d_model)
    g = (n_groups,) if n_groups else ()
    pre = (None,) if n_groups else ()
    add = builder.add
    add({}, path + ["up"], g + (d_model, 2 * du), pre + (sh.DATA, sh.MODEL))
    add({}, path + ["wq"], g + (du, du), pre + (sh.MODEL, None))
    add({}, path + ["wk"], g + (du, du), pre + (sh.MODEL, None))
    add({}, path + ["wv"], g + (du, du), pre + (sh.MODEL, None))
    add({}, path + ["wi"], g + (du, n_heads), pre + (sh.MODEL, None))
    add({}, path + ["wf"], g + (du, n_heads), pre + (sh.MODEL, None))
    add({}, path + ["bi"], g + (n_heads,), pre + (None,), init="zeros")
    add({}, path + ["bf"], g + (n_heads,), pre + (None,),
        init=lambda k, s: jnp.full(s, 3.0))  # forget-gate bias -> remember
    add({}, path + ["down"], g + (du, d_model), pre + (sh.MODEL, sh.DATA))


def _mlstm_chunk(q, k, v, li, lf, C0, n0, m0):
    """One chunk of chunkwise-parallel mLSTM.
    q,k,v [B,H,L,hd]; li,lf log gates [B,H,L]; states C0 [B,H,hd,hd],
    n0 [B,H,hd], m0 [B,H].  Returns y [B,H,L,hd] + new states (f32)."""
    B, H, L, hd = q.shape
    f_cum = jnp.cumsum(lf, axis=-1)                       # log prod f_1..t
    # decay from chunk start to t (inclusive), and total chunk decay
    g_t = f_cum                                            # [B,H,L]
    g_all = f_cum[..., -1]
    # intra-chunk log decay matrix D[t,s] = sum_{u=s+1..t} lf_u + li_s  (s<=t)
    D = g_t[..., :, None] - g_t[..., None, :] + li[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(mask, D, -jnp.inf)
    # inter-chunk term decay: a_t = g_t + m0
    inter = g_t + m0[..., None]
    m_new = jnp.maximum(D.max(-1), inter)                 # [B,H,L] running stabiliser
    Dn = jnp.exp(D - m_new[..., None])                    # [B,H,L,L]
    an = jnp.exp(inter - m_new)                           # [B,H,L]
    scale = hd ** -0.5
    s = jnp.einsum("bhld,bhsd->bhls", q, k) * scale       # [B,H,L,L]
    num = jnp.einsum("bhls,bhsd->bhld", s * Dn, v) \
        + jnp.einsum("bhld,bhde->bhle", q * an[..., None] * scale, C0)
    # normaliser: n_t = sum_s Dn * (q.k) + an * (q.n0)
    nq = jnp.einsum("bhls,bhsd,bhld->bhl", Dn, k, q) * scale \
        + jnp.einsum("bhd,bhld->bhl", n0, q * an[..., None] * scale)
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m_new))
    y = num / denom[..., None]
    # chunk-final states
    m_out = jnp.maximum(g_all + m0, (g_all[..., None] - g_t + li).max(-1))
    wC = jnp.exp(g_all[..., None] - g_t + li - m_out[..., None])   # [B,H,L]
    C_new = jnp.exp(g_all + m0 - m_out)[..., None, None] * C0 \
        + jnp.einsum("bhl,bhld,bhle->bhde", wC, k, v)
    n_new = jnp.exp(g_all + m0 - m_out)[..., None] * n0 \
        + jnp.einsum("bhl,bhld->bhd", wC, k)
    return y, C_new, n_new, m_out


def mlstm_apply(p, x, *, n_heads: int, cfg: XLSTMConfig, mode="train",
                state=None):
    B, S, D = x.shape
    du = p["wq"].shape[0]
    hd = du // n_heads
    uz = x @ p["up"]
    u, z = jnp.split(uz, 2, axis=-1)                      # [B,S,du]
    u = sh.shard(u, sh.BATCH, None, sh.MODEL)

    def heads(w):
        return (u @ w).reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(p["wq"]), heads(p["wk"]), heads(p["wv"])
    li = (u @ p["wi"] + p["bi"]).transpose(0, 2, 1).astype(jnp.float32)  # log-space input gate
    lf = jax.nn.log_sigmoid((u @ p["wf"] + p["bf"]).transpose(0, 2, 1).astype(jnp.float32))

    if mode in ("train", "prefill"):
        L = min(cfg.chunk, S)
        n = S // L
        rem = S - n * L
        sl = lambda a, lo, hi: a[:, :, lo:hi]
        qc = sl(q, 0, n * L).reshape(B, n_heads, n, L, hd).transpose(2, 0, 1, 3, 4)
        kc = sl(k, 0, n * L).reshape(B, n_heads, n, L, hd).transpose(2, 0, 1, 3, 4)
        vc = sl(v, 0, n * L).reshape(B, n_heads, n, L, hd).transpose(2, 0, 1, 3, 4)
        lic = sl(li, 0, n * L).reshape(B, n_heads, n, L).transpose(2, 0, 1, 3)
        lfc = sl(lf, 0, n * L).reshape(B, n_heads, n, L).transpose(2, 0, 1, 3)
        C0 = jnp.zeros((B, n_heads, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, n_heads, hd), jnp.float32)
        m0 = jnp.zeros((B, n_heads), jnp.float32)

        def body(carry, xs):
            C, nrm, m = carry
            y, C, nrm, m = _mlstm_chunk(xs[0].astype(jnp.float32),
                                        xs[1].astype(jnp.float32),
                                        xs[2].astype(jnp.float32),
                                        xs[3], xs[4], C, nrm, m)
            return (C, nrm, m), y

        (C, nrm, m), ys = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
        y = ys.transpose(1, 2, 0, 3, 4).reshape(B, n_heads, n * L, hd)
        if rem:
            y_r, C, nrm, m = _mlstm_chunk(
                sl(q, n * L, S).astype(jnp.float32),
                sl(k, n * L, S).astype(jnp.float32),
                sl(v, n * L, S).astype(jnp.float32),
                sl(li, n * L, S), sl(lf, n * L, S), C, nrm, m)
            y = jnp.concatenate([y, y_r], axis=2)
        y = y.transpose(0, 2, 1, 3).reshape(B, S, du).astype(x.dtype)
        out = (jax.nn.silu(z) * y) @ p["down"]
        if mode == "prefill":
            return out, {"C": C, "n": nrm, "m": m}
        return out, None

    # decode step
    C, nrm, m = state["C"], state["n"], state["m"]
    q1, k1, v1 = q[:, :, 0], k[:, :, 0], v[:, :, 0]       # [B,H,hd]
    li1, lf1 = li[:, :, 0], lf[:, :, 0]
    m_new = jnp.maximum(lf1 + m, li1)
    fw = jnp.exp(lf1 + m - m_new)
    iw = jnp.exp(li1 - m_new)
    C = fw[..., None, None] * C + iw[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k1.astype(jnp.float32), v1.astype(jnp.float32))
    nrm = fw[..., None] * nrm + iw[..., None] * k1.astype(jnp.float32)
    scale = hd ** -0.5
    num = jnp.einsum("bhd,bhde->bhe", q1.astype(jnp.float32) * scale, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", nrm,
                                         q1.astype(jnp.float32) * scale)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, du).astype(x.dtype)
    out = (jax.nn.silu(z) * y) @ p["down"]
    return out, {"C": C, "n": nrm, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(builder, path, d_model: int, n_heads: int, n_groups: int):
    """Recurrent tensor parallelism by HEAD sharding: the sLSTM recurrence
    is block-diagonal per head (einsum contracts d->e WITHIN a head), so
    `model`-sharding the HEAD dim of the recurrent matrices makes the whole
    scan communication-free — each shard owns H/m heads end to end, and the
    recurrent-weight cotangents accumulate shard-locally (no per-timestep
    psum of the full weight grad; the scan body runs under shard_map, see
    ``slstm_apply``).  The gate projections stay output-dim sharded, which
    under the head layout is the same elements grouped head-major."""
    hd = d_model // n_heads
    g = (n_groups,) if n_groups else ()
    pre = (None,) if n_groups else ()
    add = builder.add
    for gate in ("i", "f", "z", "o"):
        add({}, path + [f"w{gate}"], g + (d_model, d_model),
            pre + (sh.DATA, sh.MODEL))
        add({}, path + [f"r{gate}"], g + (n_heads, hd, hd),
            pre + (sh.MODEL, None, None))
        add({}, path + [f"b{gate}"], g + (d_model,), pre + (sh.MODEL,),
            init="zeros" if gate != "f" else (lambda k, s: jnp.full(s, 3.0)))
    add({}, path + ["down"], g + (d_model, d_model), pre + (sh.MODEL, sh.DATA))


def _slstm_step(p, carry, xt):
    """One sLSTM time step.  xt: tuple of [B,H,hd] pre-projected gate inputs.

    Everything here is per-head (the einsum contracts within a head), so a
    head-sharded caller can run this shard-locally with H/m heads."""
    c, n, h, m = carry                                    # [B,H,hd] each

    def rec(w, hh):  # block-diagonal recurrent projection
        return jnp.einsum("bhd,hde->bhe", hh, w)

    xi, xf, xz, xo = xt
    hi = h
    i_t = xi + rec(p["ri"], hi)
    f_t = xf + rec(p["rf"], hi)
    z_t = jnp.tanh(xz + rec(p["rz"], hi))
    o_t = jax.nn.sigmoid(xo + rec(p["ro"], hi))
    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + m, i_t)
    i_w = jnp.exp(i_t - m_new)
    f_w = jnp.exp(lf + m - m_new)
    c_new = f_w * c + i_w * z_t
    n_new = jnp.maximum(f_w * n + i_w, jnp.exp(-m_new))
    h_new = o_t * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def _scan_slstm(rp, xs, carry0):
    """Scan the sLSTM over time.  xs: tuple of [S,B,H,hd] gate inputs."""
    def body(carry, xt):
        new = _slstm_step(rp, carry, xt)
        return new, new[2]

    return jax.lax.scan(body, carry0, xs)


def _slstm_block(x, ws, bs, rp, down, carry0, *, model_axis, out_dtype):
    """The whole sLSTM block, shard-local: gate projections (output dim =
    this shard's heads), the recurrent scan over those heads, and the down
    projection (partial over the model axis, psummed here)."""
    B, S, _ = x.shape
    Hl, hd = carry0[0].shape[1], carry0[0].shape[2]
    xs = tuple((x @ w + b).swapaxes(0, 1).astype(jnp.float32)
               .reshape(S, B, Hl, hd) for w, b in zip(ws, bs))
    carry, hs = _scan_slstm(rp, xs, carry0)
    y = hs.swapaxes(0, 1).reshape(B, S, Hl * hd).astype(out_dtype)
    out = y @ down
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out, carry


def _head_shard_mesh(n_heads: int):
    """Mesh to head-shard the sLSTM block over, or None for the plain path.

    The recurrence is communication-free only if each `model` shard owns
    whole heads; when the mesh is absent, the model axis is vmap-excluded,
    or H doesn't divide, fall back to the replicated scan (GSPMD then
    partitions the time-parallel projections only, which is correct — the
    divergence this guards against came from GSPMD transposing the scan
    with model-sharded recurrent weights, not from the fallback)."""
    mesh = sh.get_mesh()
    if mesh is None or sh.MODEL not in mesh.axis_names:
        return None
    if sh.MODEL in sh.excluded_axes():
        return None
    m = mesh.shape[sh.MODEL]
    if m <= 1 or n_heads % m != 0:
        return None
    return mesh


def slstm_apply(p, x, *, n_heads: int, mode="train", state=None):
    B, S, D = x.shape
    H, hd = n_heads, D // n_heads

    if state is None:
        z0 = jnp.zeros((B, n_heads, hd), jnp.float32)
        state = {"c": z0, "n": z0 + 1e-6, "h": z0, "m": z0}
    carry0 = (state["c"], state["n"], state["h"], state["m"])
    rp = {k: p[k] for k in ("ri", "rf", "rz", "ro")}

    mesh = _head_shard_mesh(n_heads) if mode in ("train", "prefill") else None
    if mesh is not None:
        # One shard_map over the whole block, moe-style: heads manual over
        # the model axis, tokens over whichever batch axes divide B.  Every
        # cotangent that crosses the boundary does so along a MENTIONED
        # axis (tokens) or replicated params — with check_rep=False, an
        # output left unmentioned on an axis gets per-shard-inconsistent
        # cotangents whenever the incoming cotangent is sharded over it
        # (exactly what the batch-sharded residual stream produces), which
        # is how the pre-shard_map backward diverged.
        P = jax.sharding.PartitionSpec
        tok = []
        rem = B
        for a in sh.batch_axes(mesh):
            if rem % mesh.shape[a] == 0:
                tok.append(a)
                rem //= mesh.shape[a]
        tok = tuple(tok) if tok else None
        ws = tuple(p[f"w{g}"] for g in "ifzo")
        bs = tuple(p[f"b{g}"] for g in "ifzo")
        fn = partial(_slstm_block, model_axis=sh.MODEL, out_dtype=x.dtype)
        out, carry = shard_map(
            fn, mesh=mesh,
            in_specs=(P(tok, None, None),
                      tuple(P(None, sh.MODEL) for _ in ws),
                      tuple(P(sh.MODEL) for _ in bs),
                      {k: P(sh.MODEL, None, None) for k in rp},
                      P(sh.MODEL, None),
                      tuple(P(tok, sh.MODEL, None) for _ in carry0)),
            out_specs=(P(tok, None, None),
                       tuple(P(tok, sh.MODEL, None) for _ in carry0)),
            check_rep=False,
        )(x, ws, bs, rp, p["down"], carry0)
        # Pin the output (and hence, through the constraint's transpose, its
        # cotangent) to exactly the sharding the shard_map declared: batch
        # axes that don't divide B stay unmentioned, and an unmentioned-axis
        # cotangent must be replicated over that axis or the transpose reads
        # inconsistent per-shard values.
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(
                mesh, P(tok, None, None)))
        st = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
        return out, (st if mode == "prefill" else None)

    xi, xf, xz, xo = (x @ p["wi"] + p["bi"], x @ p["wf"] + p["bf"],
                      x @ p["wz"] + p["bz"], x @ p["wo"] + p["bo"])
    if mode in ("train", "prefill"):
        xs = tuple(a.swapaxes(0, 1).astype(jnp.float32).reshape(S, B, H, hd)
                   for a in (xi, xf, xz, xo))
        carry, hs = _scan_slstm(rp, xs, carry0)
        y = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
        out = y @ p["down"]
        st = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
        return out, (st if mode == "prefill" else None)

    xt = tuple(a[:, 0].astype(jnp.float32).reshape(B, H, hd)
               for a in (xi, xf, xz, xo))
    carry = _slstm_step(rp, carry0, xt)
    y = carry[2].reshape(B, 1, D).astype(x.dtype)
    return y @ p["down"], {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
