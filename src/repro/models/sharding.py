"""Mesh context + sharding-constraint helpers.

The model code is written once and runs in three regimes:
  * no mesh (CPU unit tests)              -> constraints are no-ops
  * single-pod mesh ("data", "model")     -> production single pod
  * multi-pod mesh ("pod", "data", "model")

Logical axes used by the model code:
  BATCH  -> ("pod", "data") when pod present, else ("data",)
  DATA   -> "data"  (FSDP / weight-gather axis)
  MODEL  -> "model" (tensor/expert parallel axis)

`shard(x, *logical)` applies with_sharding_constraint, silently dropping
axes that do not exist in the active mesh so the same model code lowers on
every mesh (or none).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BATCH = "__batch__"   # data-parallel batch axis (pod+data in multi-pod)
DATA = "data"
MODEL = "model"
POD = "pod"

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def excluded_axes() -> frozenset:
    return getattr(_state, "exclude", frozenset())


@contextlib.contextmanager
def exclude_axes(*axes: str):
    """Drop the given mesh axes from constraint resolution while tracing —
    used inside vmap(..., spmd_axis_name=ax) bodies, where constraints may
    not mention the mapped axis (it belongs to the vmapped dim)."""
    prev = excluded_axes()
    _state.exclude = prev | set(axes)
    try:
        yield
    finally:
        _state.exclude = prev


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def batch_axes(mesh: Optional[Mesh] = None):
    """Mesh axes that together shard the global batch."""
    mesh = mesh or get_mesh()
    if mesh is None:
        return ()
    axes = (POD, DATA) if POD in mesh.axis_names else (DATA,)
    return tuple(a for a in axes if a not in excluded_axes())


def resolve(spec_entry, mesh: Mesh):
    """Map a logical axis entry to concrete mesh axes (or None)."""
    excl = excluded_axes()
    if spec_entry is None:
        return None
    if spec_entry == BATCH:
        ax = batch_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    if isinstance(spec_entry, (tuple, list)):
        kept = tuple(a for a in spec_entry
                     if a in mesh.axis_names and a not in excl)
        return kept if kept else None
    return (spec_entry if spec_entry in mesh.axis_names
            and spec_entry not in excl else None)


def pspec(*logical) -> P:
    mesh = get_mesh()
    if mesh is None:
        return P()
    return P(*(resolve(e, mesh) for e in logical))


def named_sharding(*logical) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, pspec(*logical))


def shard(x, *logical):
    """with_sharding_constraint against the active mesh (no-op without one)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec(*logical)))


def axis_size(name: str) -> int:
    mesh = get_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def fusion_axes() -> tuple:
    """Mesh axes available to shard the fused-commit row (block) dim over
    (kernels/ops shard_map wrappers).  All active mesh axes participate —
    the blocked commit stack has no model-logical layout, so every device
    should own a row slice — except axes excluded by ``exclude_axes``:
    inside a ``vmap(..., spmd_axis_name=ax)`` body those axes belong to the
    vmapped dim and may not be re-used by an inner shard_map.  Size-1 axes
    are dropped (sharding over them is a no-op that still pays shard_map
    overhead).  Empty tuple -> run the kernel unsharded."""
    mesh = get_mesh()
    if mesh is None:
        return ()
    excl = excluded_axes()
    return tuple(a for a in mesh.axis_names
                 if a not in excl and mesh.shape[a] > 1)


def flat_shard_index(axes: Sequence[str], mesh: Optional[Mesh] = None):
    """Row-major flat index of this device's shard along ``axes`` — valid
    only inside a shard_map body mapped over those axes.  uint32 so the
    fused secure-commit kernels can offset their global element index
    stream position-independently (mask PRF words must be derived from
    GLOBAL block indices, or masks would not cancel across shards).  Pass
    ``mesh`` explicitly from closures that may be traced outside the
    thread-local mesh context (kernels/ops' cached jits do)."""
    mesh = mesh or get_mesh()
    flat = jnp.uint32(0)
    for a in axes:
        flat = flat * np.uint32(mesh.shape[a]) \
            + jax.lax.axis_index(a).astype(jnp.uint32)
    return flat
