"""Shared building blocks: parameter construction, norms, activations, RoPE,
losses.  Pure JAX (no flax/optax dependency)."""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import sharding as sh

# ---------------------------------------------------------------------------
# Parameter construction.  Each call site declares the *logical* sharding of
# the parameter; ParamBuilder collects a parallel PartitionSpec tree so init
# and sharding can never drift apart.
# ---------------------------------------------------------------------------


class ParamBuilder:
    def __init__(self, rng: jax.Array, dtype, abstract: bool = False):
        self.rng = rng
        self.dtype = dtype
        self.abstract = abstract          # True -> ShapeDtypeStruct leaves only
        self.params: dict = {}
        self.specs: dict = {}

    def _split(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    def add(self, tree: dict, path: list[str], shape, logical, init="normal",
            scale: float | None = None):
        """Create one parameter at params[path]; record its logical spec."""
        if self.abstract:
            val = jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        elif init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        elif init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            val = (jax.random.normal(self._split(), shape, jnp.float32) * s).astype(self.dtype)
        elif callable(init):
            val = init(self._split(), shape).astype(self.dtype)
        else:
            raise ValueError(init)
        node, snode = self.params, self.specs
        for k in path[:-1]:
            node = node.setdefault(k, {})
            snode = snode.setdefault(k, {})
        node[path[-1]] = val
        snode[path[-1]] = tuple(logical)
        return val


def logical_to_pspec_tree(spec_tree, mesh):
    """Convert a tree of logical-axis tuples to PartitionSpecs for `mesh`."""
    def conv(logical):
        if mesh is None:
            return P()
        return P(*(sh.resolve(e, mesh) for e in logical))
    return jax.tree.map(conv, spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


def act_fn(name: str) -> Callable:
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu}[name]


def glu_mlp(x, w1, w3, w2, act: str):
    """Gated MLP. act in {swiglu, geglu}; w3 is the gate projection."""
    inner = act_fn({"swiglu": "silu", "geglu": "gelu"}[act])
    h = inner(x @ w1) * (x @ w3)
    h = sh.shard(h, sh.BATCH, None, sh.MODEL)
    return h @ w2


def plain_mlp(x, w1, w2, act: str):
    h = act_fn(act)(x @ w1)
    h = sh.shard(h, sh.BATCH, None, sh.MODEL)
    return h @ w2


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., T, ..., hd] with positions broadcastable to x's T dim.

    positions: [T] or [B, T] int32.  x layout [B, T, H, hd].
    """
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))            # [hd/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [T, hd/2] or [B,T,hd/2]
    while ang.ndim < x.ndim:                              # align to [B,T,H,hd/2]
        ang = ang[..., None, :] if ang.ndim == x.ndim - 1 else ang[None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy_logits(logits, targets, vocab: int, chunk: int = 0):
    """Mean next-token CE.  logits [B,S,Vp] (Vp >= vocab, padded cols masked),
    targets [B,S].  If chunk>0 the S dim is processed in chunks to bound the
    f32 log-softmax workspace (vocab-heavy archs, e.g. 256k gemma)."""
    vp = logits.shape[-1]

    def ce(lg, tg):
        lg = lg.astype(jnp.float32)
        if vp > vocab:
            mask = (jnp.arange(vp) >= vocab) * -1e9
            lg = lg + mask
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
        return lse - picked

    if chunk and logits.shape[1] > chunk:
        B, S = targets.shape[:2]
        n = S // chunk
        lg = logits[:, : n * chunk].reshape(B, n, chunk, vp).swapaxes(0, 1)
        tg = targets[:, : n * chunk].reshape(B, n, chunk, *targets.shape[2:]).swapaxes(0, 1)
        tot = jax.lax.scan(lambda c, xs: (c + ce(xs[0], xs[1]).sum(), None),
                           jnp.float32(0.0), (lg, tg))[0]
        rem = S - n * chunk
        if rem:
            tot = tot + ce(logits[:, n * chunk:], targets[:, n * chunk:]).sum()
        return tot / targets.size
    return ce(logits, targets).mean()


@jax.custom_vjp
def _take_matmul_bwd(table, tokens):
    return jnp.take(table, tokens, axis=0)


def _take_fwd(table, tokens):
    # the table rides along as residual only for its shape/dtype (it is a
    # live parameter anyway; residuals must be JAX types)
    return jnp.take(table, tokens, axis=0), (tokens, table)


def _take_bwd(res, g):
    tokens, table = res
    # one-hot contraction instead of scatter-add: exact (one nonzero per
    # row) and partitions cleanly under GSPMD
    oh = jax.nn.one_hot(tokens, table.shape[0], dtype=g.dtype)
    return (jnp.einsum("...v,...d->vd", oh, g).astype(table.dtype), None)


_take_matmul_bwd.defvjp(_take_fwd, _take_bwd)


def take_embedding(table, tokens):
    """Embedding lookup.  Table is [V, D] with V replicated (D may be
    model-sharded) so the gather stays local on every shard.

    When a mesh with a non-trivial `model` axis is active, the backward pass
    uses a one-hot contraction instead of the gather's scatter-add
    transpose: XLA SPMD mis-partitions that scatter when the table's D dim
    is model-sharded (NaN embed cotangents, observed with the MoE archs on
    an 8-way CPU mesh).  The forward stays a cheap O(B*S*D) gather in every
    regime; only the cotangent pays the [*, V] one-hot."""
    mesh = sh.get_mesh()
    if mesh is not None and dict(mesh.shape).get(sh.MODEL, 1) > 1:
        return _take_matmul_bwd(table, tokens)
    return jnp.take(table, tokens, axis=0)
