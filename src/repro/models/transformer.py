"""Composable decoder LM covering all assigned architecture families.

A config is compiled to a *block pattern* (list of slots, each slot =
mixer + optional FFN); layers are executed as ``lax.scan`` over
``n_layers / len(pattern)`` groups with layer-stacked parameters, keeping the
HLO small for 60-100 layer models.

Families:
  dense/audio : attn + mlp                      (audio: codebook embeds/heads)
  moe         : attn + moe
  hybrid      : mamba/attn interleave + mlp/moe (jamba)
  vlm         : attn + cross-attn every Nth     (llama-3.2-vision)
  ssm         : mlstm/slstm blocks              (xlstm)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import sharding as sh
from repro.models import xlstm as xlstm_mod
from repro.models import moe as moe_mod
from repro.models.common import (ParamBuilder, cross_entropy_logits, glu_mlp,
                                 plain_mlp, rms_norm, apply_rope,
                                 take_embedding)


@dataclass(frozen=True)
class Slot:
    mixer: str          # attn | cross | mamba | mlstm | slstm
    ffn: str            # mlp | moe | none


def block_pattern(cfg: ModelConfig) -> list[Slot]:
    if cfg.xlstm is not None:
        p = cfg.xlstm.slstm_every
        return [Slot("slstm" if i % p == p - 1 else "mlstm", "none")
                for i in range(p)]
    period = 1
    if cfg.attn_every:
        period = cfg.attn_every
    if cfg.cross_attn_every:
        period = math.lcm(period, cfg.cross_attn_every)
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.every)
    slots = []
    for i in range(period):
        if cfg.attn_every:
            mixer = "attn" if i % cfg.attn_every == cfg.attn_every - 1 else "mamba"
        elif cfg.cross_attn_every:
            mixer = "cross" if i % cfg.cross_attn_every == cfg.cross_attn_every - 1 else "attn"
        else:
            mixer = "attn"
        ffn = "mlp"
        if cfg.moe is not None and i % cfg.moe.every == cfg.moe.every - 1:
            ffn = "moe"
        slots.append(Slot(mixer, ffn))
    return slots


class LM:
    def __init__(self, cfg: ModelConfig, unroll: bool = False):
        self.cfg = cfg
        self.pattern = block_pattern(cfg)
        assert cfg.n_layers % len(self.pattern) == 0, (
            cfg.name, cfg.n_layers, len(self.pattern))
        self.n_groups = cfg.n_layers // len(self.pattern)
        # unroll=True replaces the scan-over-groups with a python loop —
        # used to validate the analytic cost model against cost_analysis()
        # (XLA counts while bodies once, so only unrolled builds measure
        # true totals).
        self.unroll = unroll
        # shard attention heads over `model` only when divisible by the
        # largest production model-axis (16); else attention is replicated
        # across `model` (MLP stays TP) — see DESIGN.md §6.
        self.attn_tp = cfg.n_heads % 16 == 0
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------ init
    def init(self, rng):
        params, _ = self._build_concrete(rng)
        return params

    @cached_property
    def logical_specs(self):
        """Tree of logical-axis tuples (same structure as params)."""
        _, specs = self._build_concrete(jax.random.PRNGKey(0), abstract=True)
        return specs

    def param_specs(self):
        """Tree of ShapeDtypeStruct (for AOT lowering without allocation)."""
        params, _ = self._build_concrete(jax.random.PRNGKey(0), abstract=True)
        return params

    def _build_concrete(self, rng, abstract: bool = False):
        cfg = self.cfg
        pb = ParamBuilder(rng, self.dtype, abstract=abstract)
        D, V = cfg.d_model, cfg.vocab
        Vp = cfg.vocab_padded
        H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
        G = self.n_groups
        emb_scale = 1.0 / math.sqrt(D)

        if cfg.n_codebooks:
            pb.add({}, ["embed"], (cfg.n_codebooks, V, D), (None, None, sh.MODEL),
                   scale=emb_scale)
            pb.add({}, ["unembed"], (D, cfg.n_codebooks * Vp), (sh.DATA, sh.MODEL))
        else:
            pb.add({}, ["embed"], (V, D), (None, sh.MODEL), scale=emb_scale)
            pb.add({}, ["unembed"], (D, Vp), (sh.DATA, sh.MODEL))
        pb.add({}, ["final_norm"], (D,), (None,), init="ones")

        model_ax = sh.MODEL if self.attn_tp else None
        for si, slot in enumerate(self.pattern):
            base = ["layers", f"slot{si}"]
            pb.add({}, base + ["norm1"], (G, D), (None, None), init="ones")
            if slot.mixer in ("attn", "cross"):
                pb.add({}, base + ["wq"], (G, D, H * hd), (None, sh.DATA, model_ax))
                pb.add({}, base + ["wk"], (G, D, KV * hd), (None, sh.DATA, None))
                pb.add({}, base + ["wv"], (G, D, KV * hd), (None, sh.DATA, None))
                pb.add({}, base + ["wo"], (G, H * hd, D), (None, model_ax, sh.DATA))
            elif slot.mixer == "mamba":
                mamba_mod.init_mamba(pb, base + ["mamba"], D, cfg.mamba, G)
            elif slot.mixer == "mlstm":
                xlstm_mod.init_mlstm(pb, base + ["mlstm"], D, H, cfg.xlstm, G)
            elif slot.mixer == "slstm":
                xlstm_mod.init_slstm(pb, base + ["slstm"], D, H, G)
            if slot.ffn != "none":
                pb.add({}, base + ["norm2"], (G, D), (None, None), init="ones")
            if slot.ffn == "mlp":
                F = cfg.d_ff
                pb.add({}, base + ["w1"], (G, D, F), (None, sh.DATA, sh.MODEL))
                if cfg.act in ("swiglu", "geglu"):
                    pb.add({}, base + ["w3"], (G, D, F), (None, sh.DATA, sh.MODEL))
                pb.add({}, base + ["w2"], (G, F, D), (None, sh.MODEL, sh.DATA))
            elif slot.ffn == "moe":
                moe_mod.init_moe(pb, base + ["moe"], D, cfg.moe, G)
        return pb.params, pb.specs

    # -------------------------------------------------------------- embedding
    def embed(self, params, tokens):
        if self.cfg.n_codebooks:
            # tokens [B, S, n_cb] -> summed codebook embeddings
            parts = [take_embedding(params["embed"][c], tokens[..., c])
                     for c in range(self.cfg.n_codebooks)]
            x = sum(parts)
        else:
            x = take_embedding(params["embed"], tokens)
        return sh.shard(x, sh.BATCH, None, None)

    def logits(self, params, x):
        lg = x @ params["unembed"]
        if self.cfg.n_codebooks:
            lg = lg.reshape(*lg.shape[:-1], self.cfg.n_codebooks, self.cfg.vocab_padded)
        return lg

    # ------------------------------------------------------------------ slots
    def _attn(self, p, x, *, positions, window, mode, cache=None, pos=None,
              patches=None, cross=False):
        cfg = self.cfg
        B, S, D = x.shape
        H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
        q = (x @ p["wq"]).reshape(B, S, H, hd)
        if cross:
            kv_src = patches.astype(x.dtype)
            if mode == "decode":
                k, v = cache["k"], cache["v"]
            else:
                k = (kv_src @ p["wk"]).reshape(B, -1, KV, hd)
                v = (kv_src @ p["wv"]).reshape(B, -1, KV, hd)
            out = attn.cross_attend(q, k, v)
            new_cache = {"k": k, "v": v} if mode in ("prefill", "decode") else None
            return (out.reshape(B, S, H * hd) @ p["wo"]), new_cache

        k = (x @ p["wk"]).reshape(B, S, KV, hd)
        v = (x @ p["wv"]).reshape(B, S, KV, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

        if mode == "decode":
            S_c = cache["k"].shape[1]
            slot = pos % S_c if window else pos
            kc = attn.cache_write(cache["k"], k, slot)
            vc = attn.cache_write(cache["v"], v, slot)
            kc = sh.shard(kc, sh.BATCH, sh.MODEL, None, None)
            vc = sh.shard(vc, sh.BATCH, sh.MODEL, None, None)
            out = attn.decode_attend(q[:, 0], kc, vc, pos, window=window)
            out = out[:, None]                       # [B,1,H,hd]
            new_cache = {"k": kc, "v": vc}
        else:
            gq = H // KV
            ke = jnp.repeat(k, gq, axis=2)
            ve = jnp.repeat(v, gq, axis=2)
            m_ax = sh.MODEL if self.attn_tp else None
            q = sh.shard(q, sh.BATCH, None, m_ax, None)
            ke = sh.shard(ke, sh.BATCH, None, m_ax, None)
            ve = sh.shard(ve, sh.BATCH, None, m_ax, None)
            out = attn.attend(q, ke, ve, causal=True, window=window)
            new_cache = None
            if mode == "prefill":
                S_max = cache["k"].shape[1]
                if window:
                    # fill ring buffer with the last `window` positions
                    start = S - S_max if S >= S_max else 0
                    ks, vs = k[:, start:], v[:, start:]
                    # place so that slot = pos % S_max lines up
                    roll = (start % S_max)
                    kc = jnp.zeros_like(cache["k"]).at[:, :ks.shape[1]].set(
                        ks.astype(cache["k"].dtype))
                    vc = jnp.zeros_like(cache["v"]).at[:, :vs.shape[1]].set(
                        vs.astype(cache["v"].dtype))
                    kc = jnp.roll(kc, roll, axis=1)
                    vc = jnp.roll(vc, roll, axis=1)
                else:
                    kc = attn.cache_write(cache["k"],
                                          k.astype(cache["k"].dtype), 0)
                    vc = attn.cache_write(cache["v"],
                                          v.astype(cache["v"].dtype), 0)
                new_cache = {"k": kc, "v": vc}
        return (out.reshape(B, S, H * hd) @ p["wo"]), new_cache

    def _ffn(self, slot, p, x, mode):
        cfg = self.cfg
        if slot.ffn == "mlp":
            if cfg.act in ("swiglu", "geglu"):
                return glu_mlp(x, p["w1"], p["w3"], p["w2"], cfg.act), 0.0
            return plain_mlp(x, p["w1"], p["w2"], cfg.act), 0.0
        moe_mode = "gather_tokens" if mode == "decode" else "gather_weights"
        return moe_mod.moe_apply(p["moe"], x, cfg=cfg.moe, act=cfg.act,
                                 mode=moe_mode)

    def _apply_slot(self, slot: Slot, p, x, *, mode, positions=None, cache=None,
                    pos=None, patches=None):
        cfg = self.cfg
        aux = 0.0
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        window = cfg.sliding_window
        if slot.mixer in ("attn", "cross"):
            out, new_cache = self._attn(
                p, h, positions=positions, window=window if slot.mixer == "attn" else 0,
                mode=mode, cache=cache, pos=pos, patches=patches,
                cross=slot.mixer == "cross")
        elif slot.mixer == "mamba":
            out, new_cache = mamba_mod.mamba_apply(
                p["mamba"], h, cfg=cfg.mamba, mode=mode, state=cache)
        elif slot.mixer == "mlstm":
            out, new_cache = xlstm_mod.mlstm_apply(
                p["mlstm"], h, n_heads=cfg.n_heads, cfg=cfg.xlstm, mode=mode,
                state=cache)
        elif slot.mixer == "slstm":
            out, new_cache = xlstm_mod.slstm_apply(
                p["slstm"], h, n_heads=cfg.n_heads, mode=mode, state=cache)
        else:
            raise ValueError(slot.mixer)
        x = x + out
        if slot.ffn != "none":
            h = rms_norm(x, p["norm2"], cfg.norm_eps)
            out, aux = self._ffn(slot, p, h, mode)
            x = x + out
        x = sh.shard(x, sh.BATCH, None, None)
        return x, new_cache, aux

    # ---------------------------------------------------------------- forward
    def _backbone(self, params, x, *, mode, positions=None, caches=None,
                  pos=None, patches=None, remat=True):
        """Scan over layer groups.  Returns (x, new_caches, aux_sum)."""
        n_slots = len(self.pattern)

        def group_fn(carry, xs):
            x, aux = carry
            gp, gc = xs
            new_c = {}
            for si, slot in enumerate(self.pattern):
                key = f"slot{si}"
                c = gc.get(key) if gc is not None else None
                x, nc, a = self._apply_slot(
                    slot, gp[key], x, mode=mode, positions=positions,
                    cache=c, pos=pos, patches=patches)
                aux = aux + a
                if nc is not None:
                    new_c[key] = nc
            return (x, aux), new_c

        fn = jax.checkpoint(group_fn) if (remat and mode == "train") else group_fn
        caches_xs = caches if caches is not None else {}
        if self.unroll:
            carry = (x, jnp.float32(0.0))
            outs = []
            for g in range(self.n_groups):
                xs = jax.tree.map(lambda a: a[g],
                                  (params["layers"], caches_xs))
                carry, yc = fn(carry, xs)
                outs.append(yc)
            (x, aux) = carry
            new_caches = (jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
                          if outs and jax.tree.leaves(outs[0]) else {})
        else:
            (x, aux), new_caches = jax.lax.scan(
                fn, (x, jnp.float32(0.0)), (params["layers"], caches_xs))
        return x, new_caches, aux / self.cfg.n_layers

    # ------------------------------------------------------------------ train
    def loss_fn(self, params, batch):
        """batch: tokens [B,S(,ncb)] int32, targets same, optional patches."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self.embed(params, tokens)
        B, S = tokens.shape[0], tokens.shape[1]
        positions = jnp.arange(S)
        x, _, aux = self._backbone(params, x, mode="train", positions=positions,
                                   patches=batch.get("patches"))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        # chunked CE fused with the unembed matmul (bounds the f32 workspace)
        chunk = 512 if S * cfg.vocab_padded > (1 << 24) else 0
        loss = self._ce_from_hidden(params, x, batch["targets"], chunk)
        if cfg.moe is not None:
            loss = loss + cfg.moe.load_balance_coef * aux
        return loss, {"ce": loss, "aux": aux}

    def _ce_from_hidden(self, params, x, targets, chunk):
        cfg = self.cfg
        if not chunk or x.shape[1] <= chunk:
            lg = self.logits(params, x)
            lg = sh.shard(lg, sh.BATCH, None, sh.MODEL) if not cfg.n_codebooks \
                else sh.shard(lg, sh.BATCH, None, None, sh.MODEL)
            return cross_entropy_logits(lg, targets, cfg.vocab)
        B, S = targets.shape[0], targets.shape[1]
        n = S // chunk
        xs = x[:, :n * chunk].reshape(B, n, chunk, -1).swapaxes(0, 1)
        tg = targets[:, :n * chunk].reshape(
            B, n, chunk, *targets.shape[2:]).swapaxes(0, 1)

        def body(tot, xs_):
            xc, tc = xs_
            lg = self.logits(params, xc)
            l = cross_entropy_logits(lg, tc, cfg.vocab)
            return tot + l, None

        tot, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, tg))
        loss = tot / n
        rem = S - n * chunk
        if rem:
            lg = self.logits(params, x[:, n * chunk:])
            loss = (loss * n * chunk + cross_entropy_logits(
                lg, targets[:, n * chunk:], cfg.vocab) * rem) / S
        return loss

    # ---------------------------------------------------------------- serving
    def cache_len(self, s_max: int) -> int:
        w = self.cfg.sliding_window
        return min(w, s_max) if w else s_max

    def init_decode_state(self, B: int, s_max: int, dtype=None):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.decode_state_specs(B, s_max, dtype))

    def decode_state_specs(self, B: int, s_max: int, dtype=None):
        cfg = self.cfg
        dt = dtype or self.dtype
        KV, hd, H = cfg.kv_heads, cfg.hd, cfg.n_heads
        G = self.n_groups
        S_c = self.cache_len(s_max)
        sds = jax.ShapeDtypeStruct
        slots = {}
        for si, slot in enumerate(self.pattern):
            key = f"slot{si}"
            if slot.mixer == "attn":
                slots[key] = {"k": sds((G, B, S_c, KV, hd), dt),
                              "v": sds((G, B, S_c, KV, hd), dt)}
            elif slot.mixer == "cross":
                slots[key] = {"k": sds((G, B, cfg.n_patches, KV, hd), dt),
                              "v": sds((G, B, cfg.n_patches, KV, hd), dt)}
            elif slot.mixer == "mamba":
                di = cfg.mamba.expand * cfg.d_model
                slots[key] = {"conv": sds((G, B, cfg.mamba.d_conv - 1, di), dt),
                              "h": sds((G, B, di, cfg.mamba.d_state), jnp.float32)}
            elif slot.mixer == "mlstm":
                du = int(cfg.xlstm.proj_factor * cfg.d_model)
                hdu = du // H
                slots[key] = {"C": sds((G, B, H, hdu, hdu), jnp.float32),
                              "n": sds((G, B, H, hdu), jnp.float32),
                              "m": sds((G, B, H), jnp.float32)}
            elif slot.mixer == "slstm":
                hds = cfg.d_model // H
                slots[key] = {k: sds((G, B, H, hds), jnp.float32)
                              for k in ("c", "n", "h", "m")}
        return slots

    def state_logical_specs(self, B: int, s_max: int):
        """Logical sharding for decode state (cache S over MODEL, batch over BATCH)."""
        def spec_for(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            slot_key = path[0].key if hasattr(path[0], "key") else str(path[0])
            si = int(slot_key.replace("slot", ""))
            mixer = self.pattern[si].mixer
            if mixer == "attn" and name in ("k", "v"):
                return (None, sh.BATCH, sh.MODEL, None, None)
            if mixer == "cross" and name in ("k", "v"):
                return (None, sh.BATCH, None, None, None)
            if mixer == "mamba":
                return {"conv": (None, sh.BATCH, None, sh.MODEL),
                        "h": (None, sh.BATCH, sh.MODEL, None)}[name]
            if mixer == "mlstm":
                return {"C": (None, sh.BATCH, None, None, None),
                        "n": (None, sh.BATCH, None, None),
                        "m": (None, sh.BATCH, None)}[name]
            if mixer == "slstm":
                return (None, sh.BATCH, None, None)
            return tuple(None for _ in leaf.shape)
        return jax.tree_util.tree_map_with_path(
            spec_for, self.decode_state_specs(B, s_max))

    def prefill(self, params, batch, s_max: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape[0], tokens.shape[1]
        x = self.embed(params, tokens)
        positions = jnp.arange(S)
        caches = self.init_decode_state(B, s_max)
        x, new_caches, _ = self._backbone(
            params, x, mode="prefill", positions=positions, caches=caches,
            patches=batch.get("patches"))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        lg = self.logits(params, x[:, -1:])[:, 0]
        return lg[..., :cfg.vocab], new_caches

    def decode_step(self, params, state, token, pos, patches=None):
        """token [B] (audio [B,ncb]); pos scalar int32; returns (logits, state)."""
        cfg = self.cfg
        tok = token[:, None] if not cfg.n_codebooks else token[:, None, :]
        x = self.embed(params, tok)                 # [B,1,D]
        positions = jnp.array([0]) + pos
        x, new_caches, _ = self._backbone(
            params, x, mode="decode", positions=positions, caches=state,
            pos=pos, patches=patches)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        lg = self.logits(params, x[:, 0])
        return lg[..., :cfg.vocab], new_caches


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Active params per token (MoE: top_k of num_experts)."""
    total = param_count(params)
    if cfg.moe is None:
        return total

    def moe_leaves(tree):
        n = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            keys = [getattr(k, "key", str(k)) for k in path]
            if "moe" in keys and keys[-1] in ("w1", "w2", "w3"):
                n += int(np.prod(leaf.shape))
        return n

    expert_total = moe_leaves(params)
    active_frac = cfg.moe.top_k / cfg.moe.num_experts
    return total - expert_total + int(expert_total * active_frac)
