from repro.models.transformer import LM, build_model, param_count, active_param_count  # noqa: F401
from repro.models import sharding  # noqa: F401
