from repro.orchestrator.registry import ClientInfo, ResourceProfile, make_hybrid_fleet  # noqa: F401
from repro.orchestrator.selection import AdaptiveSelection, RandomSelection, get_selection  # noqa: F401
from repro.orchestrator.straggler import StragglerPolicy, apply_mitigation, simulate_round_times  # noqa: F401
from repro.orchestrator.fault import FaultConfig, FaultInjector, equivalent_preempt_rate_per_min  # noqa: F401
from repro.orchestrator.server import Orchestrator, RoundLog  # noqa: F401
from repro.orchestrator.async_server import AsyncOrchestrator, CommitLog, PendingUpdate  # noqa: F401
from repro.orchestrator.hierarchy import (  # noqa: F401
    Facility, FacilityResult, FacilityUpdate, HierarchicalOrchestrator,
    make_facilities, split_fleet,
)
from repro.orchestrator.megafleet import (  # noqa: F401
    BatchedAsyncOrchestrator, CohortFleet, CohortSpec, make_mega_fleet,
)
from repro.orchestrator.eventwindow import (  # noqa: F401
    BlockedGenerator, EventWindowOrchestrator, PendingStore,
)
