"""Straggler model + mitigation (paper §4.2).

``simulate_round_times`` produces each selected client's wall time for one
round from its resource profile (compute + transfer + queueing noise); the
two mitigations turn those times into a participation mask + round duration:

  * deadline cutoff: clients missing the budget are skipped this round,
  * partial (fastest-k) aggregation: stop once k updates have arrived.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.orchestrator.registry import ClientInfo


@dataclass
class StragglerPolicy:
    deadline_s: float = 0.0       # 0 -> no deadline
    fastest_k: int = 0            # 0 -> wait for all
    contention_sigma: float = 0.25  # lognormal compute-noise (shared nodes)


def simulate_round_times(clients: list[ClientInfo], flops_per_client: float,
                         payload_bytes: int, rng: np.random.Generator,
                         policy: StragglerPolicy) -> np.ndarray:
    times = []
    for c in clients:
        noise = rng.lognormal(0.0, policy.contention_sigma)
        compute = flops_per_client / (c.profile.compute_tflops * 1e12) * noise
        transfer = (2 * payload_bytes) / (c.profile.bandwidth_gbps * 1e9 / 8)
        times.append(compute + transfer + 2 * c.profile.latency_ms * 1e-3)
    return np.asarray(times)


def apply_mitigation(times: np.ndarray, policy: StragglerPolicy):
    """Returns (mask [C] float, round_duration_s)."""
    mask = np.ones_like(times)
    duration = times.max() if len(times) else 0.0
    if policy.fastest_k and policy.fastest_k < len(times):
        # exactly-k semantics: a `times <= kth` threshold admits every
        # client tied at the k-th time, so ties could over-fill the round.
        # Stable argsort keeps exactly k, breaking ties by client position.
        k = policy.fastest_k
        fastest = np.argsort(times, kind="stable")[:k]
        mask = np.zeros_like(times)
        mask[fastest] = 1.0
        duration = times[fastest].max()
    if policy.deadline_s:
        dl_mask = (times <= policy.deadline_s).astype(np.float64)
        mask = mask * dl_mask
        duration = min(duration, policy.deadline_s)
    return mask, float(duration)
