"""Straggler model + mitigation (paper §4.2).

``simulate_round_times`` produces each selected client's wall time for one
round from its resource profile (compute + transfer + queueing noise); the
two mitigations turn those times into a participation mask + round duration:

  * deadline cutoff: clients missing the budget are skipped this round,
  * partial (fastest-k) aggregation: stop once k updates have arrived.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.orchestrator.registry import ClientInfo


@dataclass
class StragglerPolicy:
    deadline_s: float = 0.0       # 0 -> no deadline
    fastest_k: int = 0            # 0 -> wait for all
    contention_sigma: float = 0.25  # lognormal compute-noise (shared nodes)


def attempt_time(profile, flops_per_client: float, payload_bytes: int,
                 noise: float) -> float:
    """One attempt's wall time given an already-drawn contention noise.

    Factored out of ``simulate_round_times`` so callers that SHARE a noise
    draw across identically-profiled clients (the cohort-level mega-fleet
    model) price an attempt with the exact same arithmetic."""
    compute = flops_per_client / (profile.compute_tflops * 1e12) * noise
    transfer = (2 * payload_bytes) / (profile.bandwidth_gbps * 1e9 / 8)
    return float(compute + transfer + 2 * profile.latency_ms * 1e-3)


def expected_attempt_s(clients: list[ClientInfo], flops_per_client: float,
                       payload_bytes: int, policy: StragglerPolicy) -> float:
    """Fleet-mean closed-form attempt duration, in expectation over the
    contention noise: E[lognormal(0, sigma)] = exp(sigma^2 / 2).  This is
    the duration scale that converts the injector's per-ATTEMPT fault
    probabilities into per-minute rates (fault.equivalent_preempt_rate_per_min)."""
    noise = float(np.exp(policy.contention_sigma ** 2 / 2.0))
    return float(np.mean([attempt_time(c.profile, flops_per_client,
                                       payload_bytes, noise)
                          for c in clients]))


def simulate_round_times(clients: list[ClientInfo], flops_per_client: float,
                         payload_bytes: int, rng: np.random.Generator,
                         policy: StragglerPolicy) -> np.ndarray:
    times = [attempt_time(c.profile, flops_per_client, payload_bytes,
                          rng.lognormal(0.0, policy.contention_sigma))
             for c in clients]
    return np.asarray(times)


def apply_mitigation(times: np.ndarray, policy: StragglerPolicy):
    """Returns (mask [C] float, round_duration_s)."""
    mask = np.ones_like(times)
    duration = times.max() if len(times) else 0.0
    if policy.fastest_k and policy.fastest_k < len(times):
        # exactly-k semantics: a `times <= kth` threshold admits every
        # client tied at the k-th time, so ties could over-fill the round.
        # Stable argsort keeps exactly k, breaking ties by client position.
        k = policy.fastest_k
        fastest = np.argsort(times, kind="stable")[:k]
        mask = np.zeros_like(times)
        mask[fastest] = 1.0
        duration = times[fastest].max()
    if policy.deadline_s:
        dl_mask = (times <= policy.deadline_s).astype(np.float64)
        mask = mask * dl_mask
        duration = min(duration, policy.deadline_s)
    return mask, float(duration)
