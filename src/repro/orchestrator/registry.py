"""Client registry + resource profiles (paper §4.1 "resource profiling").

A client is one federated participant: an HPC compute node (SLURM-managed,
Infiniband/ICI class links, high reliability) or a cloud VM (gRPC/DCN class
links, possibly a preemptible spot instance).  Profiles are what the
adaptive selection, straggler model and comm accounting consume.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ResourceProfile:
    compute_tflops: float          # effective local-training throughput
    bandwidth_gbps: float          # uplink to orchestrator
    latency_ms: float
    memory_gb: float
    reliability: float = 0.99      # P(finish round | selected)
    spot: bool = False             # preemptible (cloud spot) instance


@dataclass
class ClientInfo:
    cid: int
    site: str                      # "hpc" | "cloud"
    profile: ResourceProfile
    data_size: int = 0
    # rolling history (paper §4.1 "performance history")
    completions: int = 0
    failures: int = 0
    ema_round_time: float = 0.0
    last_selected_round: int = -1

    def record(self, ok: bool, round_time: float, rnd: int, ema: float = 0.3):
        if ok:
            self.completions += 1
            self.ema_round_time = (round_time if self.ema_round_time == 0
                                   else (1 - ema) * self.ema_round_time
                                   + ema * round_time)
        else:
            self.failures += 1
        self.last_selected_round = rnd

    @property
    def success_rate(self) -> float:
        n = self.completions + self.failures
        return self.completions / n if n else 1.0


def make_hybrid_fleet(n_hpc: int = 30, n_cloud: int = 30, seed: int = 0,
                      data_sizes=None) -> list[ClientInfo]:
    """The paper's testbed (§5.1): 30 SLURM nodes (Quadro RTX 6000 class) +
    30 AWS EC2 VMs (mix of p3.2xlarge GPU and t3.large CPU-only)."""
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n_hpc):
        gpu = i < int(0.7 * n_hpc)
        prof = ResourceProfile(
            compute_tflops=float(rng.normal(16.3, 1.0)) if gpu
            else float(rng.normal(1.0, 0.1)),          # RTX6000 ~16.3 TF fp32
            bandwidth_gbps=12.5,                        # 100 Gb Infiniband
            latency_ms=0.05,
            memory_gb=24.0 if gpu else 8.0,
            reliability=0.995,
        )
        fleet.append(ClientInfo(len(fleet), "hpc", prof))
    for i in range(n_cloud):
        gpu = i < int(0.5 * n_cloud)
        prof = ResourceProfile(
            compute_tflops=float(rng.normal(15.7, 1.5)) if gpu
            else float(rng.normal(0.4, 0.05)),         # p3.2xlarge V100 / t3.large
            bandwidth_gbps=float(rng.uniform(0.5, 1.25)),
            latency_ms=float(rng.uniform(5, 40)),
            memory_gb=16.0 if gpu else 8.0,
            reliability=0.98,
            spot=bool(rng.random() < 0.4),
        )
        fleet.append(ClientInfo(len(fleet), "cloud", prof))
    if data_sizes is not None:
        for c, s in zip(fleet, data_sizes):
            c.data_size = int(s)
    return fleet
