"""Mega-fleet batched async engine: the event loop at 100k+ clients.

``AsyncOrchestrator`` processes one Python-level event per client attempt
and pays a device round-trip per update (the ``float(loss)`` sync inside
``_train_client``) — profiling shows those syncs plus the O(N) per-dispatch
selection scan dominating wall time from ~1k clients.  This module keeps
the event-exact semantics (heap order, RNG streams, commit policy,
checkpoint format) and changes only WHERE the work happens:

  * deferred training — ``_train_client`` records a ``_TrainJob`` (params
    snapshot ref, host batches, pre-split jax key) instead of running the
    jit'd update; jobs are materialized lazily at the next commit/checkpoint
    in power-of-two vmap buckets grouped by params version, with ONE host
    sync per bucket for the losses.  Every host-side RNG draw (selection,
    work time, fault dice, batch sampling, jrng split) still happens at
    dispatch in the legacy order, so each stream's sequence is untouched —
    and a vmap lane is bit-identical to the single-example call, so the
    engine is bit-identical to the per-event loop (pinned by
    tests/test_megafleet_equivalence.py, including secure-agg, the
    scheduler backend, faults and kill/--resume).
  * batched top-up — the initial concurrency fill prices all dispatches
    through ``ExecutionBackend.execute_batch`` (one vectorised noise draw;
    one pool-clone lookahead under the scheduler backend).
  * cohort fleet model (populations >= 10k) — ``CohortFleet`` materializes
    a ``ClientInfo`` only when a client first dispatches, dispatch picks
    uniformly over IDLE clients in O(#cohorts) (the per-client adaptive
    scoring loop is the 1k-fleet bottleneck and is O(N) by construction),
    and identically-profiled clients SHARE sampled duration/fault draws in
    blocks of ``cohort_share_draws``.  Cohort mode is an explicit modelling
    approximation — faults arrive correlated within a share-block and
    selection is uniform — so it is NOT legacy-bit-identical; it is
    deterministic and checkpoint/resume-exact, which the scale tests pin.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_round import build_client_update_step
from repro.optim import get_client_optimizer
from repro.orchestrator.async_server import AsyncOrchestrator
from repro.orchestrator.registry import ClientInfo, ResourceProfile
from repro.orchestrator.straggler import attempt_time


# ---------------------------------------------------------------- cohorts
@dataclass(frozen=True)
class CohortSpec:
    """One block of identically-provisioned clients."""
    name: str
    site: str                      # "hpc" | "cloud"
    count: int
    profile: ResourceProfile


class CohortFleet:
    """A lazy, list-like fleet: ``len``/indexing like ``list[ClientInfo]``,
    but a client object exists only once it has dispatched.  Client ids are
    contiguous per cohort (cohort j owns [offset(j), offset(j)+count))."""

    def __init__(self, cohorts: list[CohortSpec]):
        self.cohorts = [c for c in cohorts if c.count > 0]
        if not self.cohorts:
            raise ValueError("CohortFleet needs at least one non-empty cohort")
        self._offsets = np.cumsum([0] + [c.count for c in self.cohorts])
        self._live: dict[int, ClientInfo] = {}

    def __len__(self) -> int:
        return int(self._offsets[-1])

    def _check(self, cid: int):
        if not 0 <= cid < len(self):
            raise IndexError(cid)

    def cohort_of(self, cid: int) -> int:
        self._check(cid)
        return int(np.searchsorted(self._offsets, cid, side="right") - 1)

    def offset(self, j: int) -> int:
        return int(self._offsets[j])

    def __getitem__(self, cid: int) -> ClientInfo:
        self._check(cid)
        c = self._live.get(cid)
        if c is None:
            spec = self.cohorts[self.cohort_of(cid)]
            c = self._live[cid] = ClientInfo(cid, spec.site, spec.profile)
        return c

    @property
    def live(self) -> dict[int, ClientInfo]:
        """Materialized clients (those that ever dispatched) — what the
        checkpoint serialises instead of the full population."""
        return self._live


def make_mega_fleet(n_clients: int, seed: int = 0,
                    spot_frac: float = 0.4) -> CohortFleet:
    """The §5.1 hybrid testbed scaled to ``n_clients``, as cohorts.

    Same population structure as ``make_hybrid_fleet`` (half HPC with a
    70% GPU split, half cloud with a 50% GPU split and ``spot_frac``
    preemptible), but each cohort draws ONE representative profile from the
    same distributions instead of per-client draws — the cohort model's
    defining approximation."""
    rng = np.random.default_rng(seed)
    n_hpc = n_clients // 2
    n_cloud = n_clients - n_hpc
    n_hpc_gpu = int(0.7 * n_hpc)
    n_cloud_gpu = int(0.5 * n_cloud)
    n_cloud_cpu = n_cloud - n_cloud_gpu

    def cloud_prof(tf_mu, tf_sd, mem, spot):
        return ResourceProfile(
            compute_tflops=float(rng.normal(tf_mu, tf_sd)),
            bandwidth_gbps=float(rng.uniform(0.5, 1.25)),
            latency_ms=float(rng.uniform(5, 40)),
            memory_gb=mem, reliability=0.98, spot=spot)

    hpc_gpu = ResourceProfile(float(rng.normal(16.3, 1.0)), 12.5, 0.05,
                              24.0, reliability=0.995)
    hpc_cpu = ResourceProfile(float(rng.normal(1.0, 0.1)), 12.5, 0.05,
                              8.0, reliability=0.995)
    n_gpu_spot = int(round(spot_frac * n_cloud_gpu))
    n_cpu_spot = int(round(spot_frac * n_cloud_cpu))
    return CohortFleet([
        CohortSpec("hpc-gpu", "hpc", n_hpc_gpu, hpc_gpu),
        CohortSpec("hpc-cpu", "hpc", n_hpc - n_hpc_gpu, hpc_cpu),
        CohortSpec("cloud-gpu", "cloud", n_cloud_gpu - n_gpu_spot,
                   cloud_prof(15.7, 1.5, 16.0, False)),
        CohortSpec("cloud-gpu-spot", "cloud", n_gpu_spot,
                   cloud_prof(15.7, 1.5, 16.0, True)),
        CohortSpec("cloud-cpu", "cloud", n_cloud_cpu - n_cpu_spot,
                   cloud_prof(0.4, 0.05, 8.0, False)),
        CohortSpec("cloud-cpu-spot", "cloud", n_cpu_spot,
                   cloud_prof(0.4, 0.05, 8.0, True)),
    ])


class _CohortInflight(set):
    """The in-flight cid set, with an O(1) per-cohort busy counter so cohort
    dispatch never walks the set."""

    def __init__(self, fleet: CohortFleet):
        super().__init__()
        self._fleet = fleet
        self.by_cohort = np.zeros(len(fleet.cohorts), np.int64)

    def add(self, cid):
        if cid not in self:
            self.by_cohort[self._fleet.cohort_of(cid)] += 1
        super().add(cid)

    def discard(self, cid):
        if cid in self:
            self.by_cohort[self._fleet.cohort_of(cid)] -= 1
        super().discard(cid)


# ----------------------------------------------------------------- engine
@dataclass
class _TrainJob:
    """One deferred local-training call, fixed at dispatch time."""
    upd: object                    # the PendingUpdate awaiting delta/loss
    params: object                 # params snapshot REF (replaced per commit,
    #                                never mutated, so holding it is free)
    batches: dict                  # host-side sampled batches [H, b, ...]
    key: object                    # the jrng key split for this dispatch


@dataclass
class BatchedAsyncOrchestrator(AsyncOrchestrator):
    """Drop-in ``AsyncOrchestrator`` with deferred chunked-vmap training,
    batched top-up dispatch, and the cohort fleet model when ``fleet`` is a
    ``CohortFleet``.  On flat (list) fleets it is bit-identical to the
    per-event engine; on cohort fleets it is deterministic + resume-exact
    under the cohort model's shared-draw approximation."""

    train_chunk: int = 32          # max vmap lanes per materialize call
    cohort_share_draws: int = 8    # dispatches per shared duration/fault draw

    def __post_init__(self):
        super().__post_init__()
        if self.train_chunk < 1:
            raise ValueError(
                f"train_chunk must be >= 1, got {self.train_chunk}")
        if self.cohort_share_draws < 1:
            raise ValueError(f"cohort_share_draws must be >= 1, got "
                             f"{self.cohort_share_draws}")
        self._jobs: dict[int, _TrainJob] = {}     # seq -> deferred training
        self._vstep_cache: dict[int, object] = {}  # lanes -> jit(vmap(step))
        self._update_fn = build_client_update_step(
            self.loss_fn, get_client_optimizer(self.client_opt_name), self.fl)
        self._cohort_mode = isinstance(self.fleet, CohortFleet)
        self._cohort_draws: dict[int, dict] = {}  # cohort -> shared block
        if self._cohort_mode:
            self._inflight = _CohortInflight(self.fleet)
            self._cohort_counts = np.array(
                [c.count for c in self.fleet.cohorts], np.int64)

    # --------------------------------------------------- deferred training
    def _train_client(self, upd, client, params):
        """Record the training call; the jit'd update runs at materialize
        time.  All RNG draws (batch sampling, jrng split) happen HERE, in
        dispatch order, exactly like the eager engine."""
        batches = self.fed_data.sample_round([client.cid],
                                             self.fl.local_steps,
                                             self.batch_size)
        batches = jax.tree.map(lambda x: np.asarray(x[0]), batches)
        r = self._next_key()
        upd.weight = float(max(self.fed_data.client_size(client.cid), 1))
        # a restart retry re-enters here with the same seq: the stale job is
        # simply replaced (the eager engine wasted that training up front)
        self._jobs[upd.seq] = _TrainJob(upd, params, batches, r)

    def _materialize(self, seqs=None):
        """Materialize deferred jobs — all of them, or (``seqs`` given) only
        that subset, leaving the rest queued for a later call."""
        pending = (sorted(self._jobs) if seqs is None
                   else sorted(s for s in self._jobs if s in seqs))
        if not pending:
            return
        # group by params snapshot (dispatch version), preserving seq order
        # within each group; chunk each group into vmap buckets
        with self._timed("train"):
            groups: dict[int, list[_TrainJob]] = {}
            for seq in pending:
                job = self._jobs[seq]
                groups.setdefault(id(job.params), []).append(job)
            for jobs in groups.values():
                for lo in range(0, len(jobs), self.train_chunk):
                    self._run_chunk(jobs[lo:lo + self.train_chunk])
        for seq in pending:
            del self._jobs[seq]

    def _run_chunk(self, jobs: list[_TrainJob]):
        """vmap one bucket of same-snapshot jobs; one host sync (the loss
        fetch) for the whole bucket.  Buckets are padded to the next power
        of two by repeating lane 0 — a vmap lane is bit-identical to the
        single call, and padded lanes are discarded — so the compile cache
        holds log2(train_chunk) entries, not one per bucket length."""
        n = len(jobs)
        lanes = 1 << max(n - 1, 0).bit_length()
        step = self._vstep_cache.get(lanes)
        if step is None:
            step = self._vstep_cache[lanes] = jax.jit(
                jax.vmap(self._update_fn, in_axes=(None, 0, 0)))
        pick = list(range(n)) + [0] * (lanes - n)
        batches = jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)),
                               *[jobs[i].batches for i in pick])
        keys = jnp.stack([jobs[i].key for i in pick])
        deltas, losses = step(jobs[0].params, batches, keys)
        self._finish_chunk(jobs, deltas, losses)

    def _finish_chunk(self, jobs, deltas, losses):
        """Assign a bucket's results back to its updates.  ONE host sync
        (the loss fetch) per bucket; the event-window engine overrides this
        to defer even that to the commit's bundled fetch."""
        lv = np.asarray(self._host_fetch(losses))
        for i, job in enumerate(jobs):
            job.upd.delta = jax.tree.map(lambda d: d[i], deltas)
            job.upd.loss = float(lv[i])

    # ----------------------------------------------------- batched top-up
    def _top_up(self, params):
        if self._cohort_mode:
            # cohort dispatch is O(#cohorts) with amortised shared draws —
            # the per-dispatch path is already cheap, and the shared-draw
            # cache must interleave exactly as in steady-state dispatch
            return super()._top_up(params)
        with self._timed("dispatch"):
            target = min(self.async_cfg.max_concurrency, len(self.fleet))
            picks = []
            for _ in range(max(0, target - len(self._inflight))):
                picked = self._pick_client(self._seq + len(picks))
                if picked is None:
                    break
                # claim the slot now so the next pick's availability view
                # matches the sequential engine's
                self._inflight.add(picked[1].cid)
                picks.append(picked)
            if not picks:
                return
            up_bytes = self._payload_bytes_cache(params)[1]
            exs = self.backend.execute_batch(
                [c for _, c in picks], self.flops_per_client_round, up_bytes,
                self.clock)
            for (client_idx, client), ex in zip(picks, exs):
                self._finish_dispatch(client_idx, client, ex, params,
                                      self.clock)

    # ----------------------------------------------------- cohort dispatch
    def _cohort_draw(self, client) -> dict:
        """The cohort's current shared draw block: one contention noise and
        one fault fate reused for ``cohort_share_draws`` dispatches."""
        j = self.fleet.cohort_of(client.cid)
        e = self._cohort_draws.get(j)
        if e is None or e["left"] <= 0:
            e = self._cohort_draws[j] = {
                "noise": float(self.rng.lognormal(
                    0.0, self.straggler.contention_sigma)),
                "fate": list(self.fault_injector.draw_fault(
                    client,
                    include_preempt=not self.backend.handles_preemption)),
                "left": int(self.cohort_share_draws)}
        return e

    def _pick_client(self, rnd: int):
        if not self._cohort_mode:
            return super()._pick_client(rnd)
        idle = self._cohort_counts - self._inflight.by_cohort
        total = int(idle.sum())
        if total <= 0:
            return None
        # cohort ∝ idle count, then a uniform idle member: exactly uniform
        # over idle clients.  Per-client adaptive scoring is O(N) per
        # dispatch by construction — at mega scale selection pressure comes
        # from the cohort weights, and uniform-over-idle is the FedAvg
        # baseline the paper's ablation uses.
        rng = self.selection.rng
        j = int(rng.choice(len(idle), p=idle / total))
        base, count = self.fleet.offset(j), int(self._cohort_counts[j])
        for _ in range(64):                        # rejection: P(hit) = idle/count
            cid = base + int(rng.integers(count))
            if cid not in self._inflight:
                break
        else:  # nearly-saturated cohort: enumerate its idle members once
            free = [c for c in range(base, base + count)
                    if c not in self._inflight]
            cid = int(free[int(rng.integers(len(free)))])
        return cid, self.fleet[cid]

    def _execute_attempt(self, client, params, now):
        if self._cohort_mode and not self.backend.handles_preemption:
            # closed-form pricing with the cohort's shared noise draw
            # (local import: repro.exec depends on this package's straggler
            # model, so a module-level import would be circular)
            from repro.exec.backend import ClientExecution
            up_bytes = self._payload_bytes_cache(params)[1]
            w = attempt_time(client.profile, self.flops_per_client_round,
                             up_bytes, self._cohort_draw(client)["noise"])
            return ClientExecution(work_s=w, run_s=w, site=client.site)
        return super()._execute_attempt(client, params, now)

    def _draw_attempt_fault(self, client):
        if not self._cohort_mode:
            return super()._draw_attempt_fault(client)
        e = self._cohort_draw(client)
        e["left"] -= 1
        failed, kind, frac = e["fate"]
        return bool(failed), str(kind), float(frac)

    # ------------------------------------------------ checkpointable state
    def engine_state(self) -> dict:
        """Engine-private state beyond the base serializer's reach.  Pending
        train jobs are materialized before any save (the serializer calls
        ``_materialize``), so only the cohort shared-draw blocks remain."""
        if not self._cohort_draws:
            return {}
        return {"cohort_draws": {str(j): dict(e)
                                 for j, e in self._cohort_draws.items()}}

    def load_engine_state(self, s: dict):
        self._cohort_draws = {
            int(j): {"noise": float(e["noise"]), "fate": list(e["fate"]),
                     "left": int(e["left"])}
            for j, e in s.get("cohort_draws", {}).items()}

    def _abandon_update(self, upd):
        # the update's delta will never be read: cancel its deferred job
        # instead of training it at the next materialize (the eager engine
        # wasted that training up front; committed results are unaffected
        # because every vmap lane is exact regardless of bucket makeup)
        self._jobs.pop(upd.seq, None)

    def _after_restore(self):
        # restored deltas are eager; cohort draw blocks were already loaded
        # by load_engine_state (or stay empty on a flat-fleet snapshot)
        super()._after_restore()
        self._jobs.clear()
        if self._cohort_mode:
            infl = _CohortInflight(self.fleet)
            for cid in self._inflight:
                infl.add(cid)
            self._inflight = infl
