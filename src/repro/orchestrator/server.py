"""The Central Orchestrator (paper §3.2): the full round loop of Algorithm 1
with adaptive selection, straggler mitigation, fault injection, comm
accounting and checkpointing wired together.

Host-side only — the heavy math is the jit'd round step from
repro.core.round; the orchestrator decides *who participates*, charges
simulated wall-clock/bytes, and manages state across rounds.  WHERE and
WHEN a client's local training runs comes from the pluggable
``ExecutionBackend`` (``repro.exec``): the closed-form straggler model by
default, or the SLURM/K8s scheduler simulation (queue waits, elastic
HPC→cloud overflow, adapter-origin spot preemptions) under
``--exec-backend scheduler``.  It is
deliberately light/stateless-restartable: everything it needs to resume
lives in the CheckpointManager.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.transport import CommAccountant, link_for_site
from repro.core.compression import payload_bytes
from repro.core.secure_agg import masked_payload_bytes
from repro.core.convergence import ConvergenceMonitor
from repro.core.round import FLConfig, build_fl_round_step
from repro.optim import get_client_optimizer, get_server_optimizer
from repro.orchestrator.fault import FaultConfig, FaultInjector
from repro.orchestrator.registry import ClientInfo
from repro.orchestrator.selection import get_selection
from repro.orchestrator.straggler import StragglerPolicy, apply_mitigation


@dataclass
class RoundLog:
    rnd: int
    selected: list
    participated: int
    duration_s: float
    client_loss: float
    delta_norm: float
    bytes_up: int
    eval_metric: float = float("nan")
    mean_queue_wait_s: float = 0.0     # scheduler backend: PENDING time
    n_overflow: int = 0                # clients placed off their home site
    n_preempted: int = 0               # adapter-origin spot reclaims


@dataclass
class Orchestrator:
    fleet: list                       # list[ClientInfo]
    fed_data: object                  # FederatedDataset
    loss_fn: Callable                 # (params, batch) -> (loss, aux)
    fl: FLConfig
    client_opt_name: str = "sgd"
    server_opt_name: str = "fedavg"
    server_opt_kw: dict = field(default_factory=dict)
    selection_name: str = "adaptive"
    straggler: StragglerPolicy = field(default_factory=StragglerPolicy)
    faults: FaultConfig = field(default_factory=FaultConfig)
    batch_size: int = 16
    flops_per_client_round: float = 1e12
    eval_fn: Optional[Callable] = None     # (params) -> float metric
    eval_every: int = 10
    checkpoint_mgr: object = None
    checkpoint_every: int = 0
    backend: object = None            # ExecutionBackend (None -> closed form)
    seed: int = 0

    def __post_init__(self):
        if self.fl.mode != "sync":
            raise ValueError(
                f"Orchestrator runs the synchronous barrier loop but got "
                f"FLConfig(mode={self.fl.mode!r}); use AsyncOrchestrator "
                f"for mode='async'")
        self.rng = np.random.default_rng(self.seed)
        self.jrng = jax.random.PRNGKey(self.seed)
        if self.backend is None:
            # local import: repro.exec consumes the straggler model from
            # this package, so a module-level import would be circular
            from repro.exec.backend import ClosedFormBackend
            self.backend = ClosedFormBackend()
        self.backend.bind(self.rng, self.straggler)
        self.selection = get_selection(self.selection_name, seed=self.seed)
        self.fault_injector = FaultInjector(self.faults, seed=self.seed + 1)
        self.comm = CommAccountant()
        self.logs: list[RoundLog] = []
        self.virtual_clock = 0.0
        client_opt = get_client_optimizer(self.client_opt_name)
        server_opt = get_server_optimizer(self.server_opt_name,
                                          **self.server_opt_kw)
        self._server_opt = server_opt
        self._round_step = jax.jit(build_fl_round_step(
            self.loss_fn, client_opt, server_opt, self.fl))

    # ------------------------------------------------------------------
    def init_server_state(self, params):
        return self._server_opt.init(params)

    def run_round(self, rnd: int, params, server_state):
        C = self.fl.num_clients
        selected = self.selection.select(self.fleet, C, rnd)
        clients = [self.fleet[c] for c in selected]

        # --- simulate system behaviour (host-side) ---
        down_bytes, up_bytes = self._payload_bytes_cache(params)
        execs = self.backend.execute_round(
            clients, self.flops_per_client_round, up_bytes,
            self.virtual_clock)
        times = np.asarray([e.duration_s for e in execs])
        mask, duration = apply_mitigation(times, self.straggler)
        self.fault_injector.step_round()
        mask = mask * self.fault_injector.survive_mask(
            clients, include_preempt=not self.backend.handles_preemption)
        if self.backend.handles_preemption:
            # spot reclaims originate from the scheduler's own event stream
            mask = mask * np.asarray([0.0 if e.preempted else 1.0
                                      for e in execs])

        # --- data + weights ---
        batches = self.fed_data.sample_round(selected, self.fl.local_steps,
                                             self.batch_size)
        batches = jax.tree.map(jnp.asarray, batches)
        weights = jnp.asarray([max(self.fed_data.client_size(c), 1)
                               for c in selected], jnp.float32)
        jmask = jnp.asarray(mask, jnp.float32)

        # --- the jit'd Algorithm-1 round ---
        self.jrng, r = jax.random.split(self.jrng)
        params, server_state, metrics = self._round_step(
            params, server_state, batches, weights, jmask, r)

        # --- accounting (links charged by PLACEMENT site, not home site) ---
        bytes_up = 0
        for ci, c in enumerate(clients):
            link = link_for_site(execs[ci].site or c.site)
            self.comm.log(rnd, c.cid, "down", down_bytes, link)
            if mask[ci] > 0:
                t = self.comm.log(rnd, c.cid, "up", up_bytes, link)
                bytes_up += up_bytes
            c.record(mask[ci] > 0, float(times[ci]), rnd)
        self.virtual_clock += duration
        # barrier closed: straggler jobs cut by the mitigation are abandoned
        self.backend.end_round(self.virtual_clock)

        log = RoundLog(
            rnd=rnd, selected=selected, participated=int(mask.sum()),
            duration_s=duration,
            client_loss=float(metrics["client_loss"]),
            delta_norm=float(metrics["delta_norm"]),
            bytes_up=bytes_up,
            mean_queue_wait_s=float(np.mean([e.queue_wait_s for e in execs]))
            if execs else 0.0,
            n_overflow=sum(e.overflowed for e in execs),
            n_preempted=sum(e.preempted for e in execs))
        self.logs.append(log)
        return params, server_state, log

    def _payload_bytes_cache(self, params):
        """(down_bytes, up_bytes): under secure_agg the uplink is the
        MASKED update — dense f32 without quantization, finite-ring words
        of quantize_bits + ceil(log2(cohort)) bits with it (integer-domain
        masking, core.pipeline) — while the params downlink stays plain."""
        if not hasattr(self, "_pb"):
            down = payload_bytes(params, self.fl.compression)
            up = (masked_payload_bytes(params, self.fl.compression,
                                       n_slots=self.fl.num_clients)
                  if self.fl.secure_agg else down)
            self._pb = (down, up)
        return self._pb

    def run(self, params, num_rounds: int, server_state=None,
            convergence_eps: float = 0.0, verbose: bool = False,
            start_round: int = 0):
        if server_state is None:
            server_state = self.init_server_state(params)
        monitor = ConvergenceMonitor(convergence_eps) if convergence_eps else None
        for rnd in range(start_round, num_rounds):
            params, server_state, log = self.run_round(rnd, params, server_state)
            if self.eval_fn and (rnd % self.eval_every == 0
                                 or rnd == num_rounds - 1):
                log.eval_metric = float(self.eval_fn(params))
            if verbose:
                print(f"round {rnd:4d} loss={log.client_loss:.4f} "
                      f"dur={log.duration_s:.1f}s part={log.participated} "
                      f"eval={log.eval_metric:.4f}")
            if self.checkpoint_mgr and self.checkpoint_every and \
                    rnd % self.checkpoint_every == 0:
                self.checkpoint_mgr.save(
                    rnd, params, server_state,
                    {"clock": self.virtual_clock,
                     "exec_backend": self.backend.name,
                     "backend_state": self.backend.state()})
            if monitor and monitor.update(log.delta_norm):
                break
        return params, server_state
