"""Vectorized event-window engine: one host sync per commit window.

``BatchedAsyncOrchestrator`` (PR 6) removed the per-event device round
trip from *training* — but the coordinator still pays, per commit window:
one Python-level ``jax.random.split`` device call per dispatch, one host
loss fetch per vmap bucket, one scalar RNG draw per work-time/fault dice,
and O(pending) heap churn over ``PendingUpdate`` objects.  Profiling at
100k clients shows exactly those costs standing between 100k and 1e6
simulated clients.  This engine removes them without changing a single
draw or event:

  * ``BlockedGenerator`` — wraps the orchestrator's and the fault
    injector's ``numpy.random.Generator`` so scalar draws are served from
    pre-drawn homogeneous blocks (one vectorized RNG call per window
    instead of one Python-level call per event).  numpy draws a block of
    n with the same values AND the same end state as n sequential scalar
    calls, and a partially-consumed block is re-synced by rewinding the
    bit generator and replaying exactly the consumed prefix — so every
    consumer (checkpoint state capture included) sees the sequential
    stream bit-for-bit (pinned by tests/test_eventwindow.py).
  * ``_KeyBlock`` — the jax key chain is advanced by a jitted
    ``lax.scan`` of sequential splits: one device call + one host fetch
    per ``window`` keys, values bit-identical to per-event splits.
  * ``PendingStore`` — pending arrivals live in a numpy structured array
    (arrival time, seq, client id, params version at dispatch, fault
    kind) with a (t, seq) index heap; ``PendingUpdate`` payloads are
    reached through a seq-keyed side table only when an event actually
    pops.  Iteration yields legacy (t, seq, upd) tuples, so the
    checkpoint serializer and the restore path work unchanged.
  * deferred loss fetch — vmap buckets keep their losses ON DEVICE
    (stacking device scalars into the commit step is transfer-free); the
    commit bundles delta_norm + every deferred loss bucket into ONE
    ``jax.device_get``.  Commits materialize only the *buffered* seqs;
    off-buffer jobs stay queued.
  * window-batched backend draws — ``ExecutionBackend.begin_window``
    reserves a window-sized RNG block for work-time draws and lets the
    scheduler backend amortize its terminal-job GC across the window.

Bit-identity with the legacy per-event engine on flat fleets — across
secure-agg, faults x recovery policies, the scheduler backend, chunked
commits, and cross-engine kill/--resume — is locked by
tests/test_megafleet_equivalence.py.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.orchestrator.megafleet import BatchedAsyncOrchestrator


# ------------------------------------------------------------- rng blocks
class BlockedGenerator:
    """Serve scalar draws from pre-drawn homogeneous blocks, bit-identical
    to the sequential ``numpy.random.Generator`` stream.

    Exactness contract (pinned by tests/test_eventwindow.py):

    * for ``random``/``uniform``/``lognormal``, numpy's block draw of n
      values equals n sequential scalar calls elementwise AND leaves the
      bit generator in the same end state;
    * a partially consumed block is ``_sync``'d by rewinding to the
      pre-block state and replaying exactly the consumed prefix, which
      recovers the sequential state bit-for-bit;
    * any other method (``choice``, ``integers``, ...) and any
      ``bit_generator`` access syncs first, so state-dependent draws and
      checkpoint save/restore see the exact sequential generator.
    """

    def __init__(self, gen: np.random.Generator, window: int = 256):
        self._gen = gen
        self._window = int(window)
        self._pending = 0            # reserve() hint for the next refill
        self._kind = None            # (name, *args) of the live block
        self._block = None
        self._i = 0
        self._state0 = None          # bit generator state before the block

    def reserve(self, n: int):
        """Size hint: at least ``n`` same-kind draws are coming; make the
        next refill big enough to serve them from one vectorized call."""
        self._pending = max(self._pending, int(n))

    def _raw(self, kind, size):
        name, args = kind[0], kind[1:]
        return getattr(self._gen, name)(*args, size=size)

    def _sync(self):
        """Return the wrapped generator to the exact sequential state."""
        if self._kind is None:
            return
        if self._i < len(self._block):
            self._gen.bit_generator.state = self._state0
            if self._i:
                self._raw(self._kind, self._i)
        self._kind = self._block = self._state0 = None
        self._i = 0

    def _refill(self, kind, n: int):
        self._sync()
        self._kind = kind
        self._state0 = self._gen.bit_generator.state
        size = max(self._window, self._pending, n)
        self._pending = 0
        self._block = self._raw(kind, size)
        self._i = 0

    def _serve(self, kind, size):
        if size is None:
            if self._kind != kind or self._i >= len(self._block):
                self._refill(kind, 1)
            v = self._block[self._i]
            self._i += 1
            return float(v)
        n = int(size)
        if self._kind != kind or self._i + n > len(self._block):
            self._refill(kind, n)
        out = self._block[self._i:self._i + n].copy()
        self._i += n
        return out

    def random(self, size=None):
        return self._serve(("random",), size)

    def uniform(self, low=0.0, high=1.0, size=None):
        return self._serve(("uniform", float(low), float(high)), size)

    def lognormal(self, mean=0.0, sigma=1.0, size=None):
        return self._serve(("lognormal", float(mean), float(sigma)), size)

    @property
    def bit_generator(self):
        # checkpoint capture/restore path: hand out the REAL bit generator,
        # sequential-exact (assignment through this property lands on it)
        self._sync()
        return self._gen.bit_generator

    def __getattr__(self, name):
        # non-blocked draws (choice, integers, normal, ...) go to the real
        # generator after an exact sync.  Only called for names not found
        # on the wrapper itself.
        gen = object.__getattribute__(self, "_gen")
        self._sync()
        return getattr(gen, name)


class _KeyBlock:
    """Amortized jax key chain: a jitted ``lax.scan`` of sequential
    ``jax.random.split`` calls yields ``window`` (chain, subkey) pairs in
    one device call + one host fetch, bit-identical to per-event splits
    (pinned by tests/test_eventwindow.py)."""

    def __init__(self, window: int = 256):
        self._window = int(window)
        self._chain = None           # [W, 2] uint32 chain states
        self._subs = None            # [W, 2] uint32 subkeys
        self._i = 0

        def _run(key):
            def step(c, _):
                nk = jax.random.split(c)
                return nk[0], (nk[0], nk[1])
            _, out = jax.lax.scan(step, key, None, length=self._window)
            return out

        self._scan = jax.jit(_run)

    def next(self, jrng, fetch=jax.device_get):
        """(subkey, new_chain_value) for one split of ``jrng``.  ``fetch``
        is the host-transfer hook (the orchestrator passes ``_host_fetch``
        so refills are billed as host syncs)."""
        if self._chain is None or self._i >= len(self._chain):
            key = jnp.asarray(np.asarray(jrng, np.uint32))
            self._chain, self._subs = fetch(self._scan(key))
            self._i = 0
        r, new = self._subs[self._i], self._chain[self._i]
        self._i += 1
        return r, new

    def reset(self):
        """Drop the precomputed chain (the chain value changed under us —
        checkpoint restore)."""
        self._chain = self._subs = None
        self._i = 0


# ----------------------------------------------------------- event store
_FAULT_CODES = {"": 0, "dropout": 1, "preempt": 2, "partition": 3}


class PendingStore:
    """Array-backed pending-arrival store, drop-in for the legacy heap of
    (arrival_time, seq, PendingUpdate) tuples.

    The hot metadata — arrival time, seq, client id, params version at
    dispatch, fault kind — lives in a numpy structured array; ordering is
    a (t, seq) index heap (floats + ints only, no object comparisons);
    the ``PendingUpdate`` payloads live in a seq-keyed dict touched only
    when an event pops.  Iteration yields legacy (t, seq, upd) tuples so
    the checkpoint serializer — and the loader, which heapifies a plain
    tuple list that ``_after_restore`` converts back — work unchanged."""

    DTYPE = np.dtype([("t", np.float64), ("seq", np.int64),
                      ("cid", np.int64), ("version", np.int64),
                      ("fault", np.int8)])

    def __init__(self, events=()):
        self._heap: list[tuple] = []
        self._rows = np.zeros(64, self.DTYPE)
        self._n = 0                          # rows used (incl. dead rows)
        self._upd: dict[int, object] = {}    # seq -> PendingUpdate
        self._row: dict[int, int] = {}       # seq -> row index
        for t, seq, upd in events:
            self.push(t, seq, upd)

    def __len__(self) -> int:
        return len(self._heap)

    def __iter__(self):
        for t, seq in self._heap:
            yield t, seq, self._upd[seq]

    def push(self, t: float, seq: int, upd):
        if self._n == len(self._rows):
            self._compact_or_grow()
        self._rows[self._n] = (t, seq, upd.cid, upd.dispatch_version,
                               _FAULT_CODES.get(upd.fault, 0))
        self._row[seq] = self._n
        self._n += 1
        self._upd[seq] = upd
        heapq.heappush(self._heap, (t, seq))

    def pop(self):
        t, seq = heapq.heappop(self._heap)
        del self._row[seq]                   # row goes dead; compacted lazily
        return t, seq, self._upd.pop(seq)

    def min_time(self):
        return self._heap[0][0] if self._heap else None

    @property
    def live(self) -> np.ndarray:
        """Structured rows of the live pending arrivals, in push order."""
        idx = np.sort(np.fromiter(self._row.values(), np.int64,
                                  len(self._row)))
        return self._rows[idx]

    def staleness(self, version: int) -> np.ndarray:
        """Commits elapsed since each pending arrival's dispatch — one
        vectorized subtract over the structured rows."""
        return np.int64(version) - self.live["version"]

    def _compact_or_grow(self):
        if len(self._row) <= len(self._rows) // 2:
            # >= half the rows are dead (popped): compact in place
            idx = np.sort(np.fromiter(self._row.values(), np.int64,
                                      len(self._row)))
            rows = self._rows[idx]
            self._rows[:len(rows)] = rows
            self._n = len(rows)
            self._row = {int(r["seq"]): i for i, r in enumerate(rows)}
        else:
            self._rows = np.concatenate(
                [self._rows, np.zeros(len(self._rows), self.DTYPE)])


# ----------------------------------------------------------------- engine
@dataclass
class EventWindowOrchestrator(BatchedAsyncOrchestrator):
    """Drop-in ``BatchedAsyncOrchestrator`` that processes events against
    window-blocked RNG streams, an array-backed pending store, an
    amortized key chain, and ONE bundled host sync per commit window.
    Bit-identical to both other engines on flat fleets; on cohort fleets
    it matches the batched engine's (deterministic, resume-exact)
    trajectory."""

    window: int = 256              # events per RNG/key/backend block

    def __post_init__(self):
        super().__post_init__()
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        # wrap the two scalar-draw streams the event loop consumes; the
        # backend holds a ref to the orchestrator rng, so re-bind it
        self.rng = BlockedGenerator(self.rng, self.window)
        self.backend.bind(self.rng, self.straggler)
        self.fault_injector.rng = BlockedGenerator(
            self.fault_injector.rng, self.window)
        self._keys = _KeyBlock(self.window)
        self._deferred = []        # [(device losses [L], bucket job list)]
        self._events = PendingStore()
        self.backend.begin_window(self.window)

    # ------------------------------------------------------- engine seams
    def _next_key(self):
        r, self.jrng = self._keys.next(self.jrng, self._host_fetch)
        return r

    def _push_event(self, t, seq, upd):
        self._events.push(t, seq, upd)

    def _pop_event(self):
        return self._events.pop()

    # --------------------------------------------------- deferred fetches
    def _finish_chunk(self, jobs, deltas, losses):
        # keep the bucket's losses ON DEVICE: stacking device scalars into
        # the commit step is transfer-free, so the only reader that needs
        # host floats is the CommitLog — served by the commit's bundled
        # fetch (or _flush_deferred for full materializes)
        for i, job in enumerate(jobs):
            job.upd.delta = jax.tree.map(lambda d: d[i], deltas)
            job.upd.loss = losses[i]
        self._deferred.append((losses, list(jobs)))

    def _assign_losses(self, buckets):
        for lv, (_, jobs) in zip(buckets, self._deferred):
            lv = np.asarray(lv)
            for i, job in enumerate(jobs):
                job.upd.loss = float(lv[i])
        self._deferred = []

    def _flush_deferred(self):
        if self._deferred:
            self._assign_losses(
                self._host_fetch([b for b, _ in self._deferred]))

    def _materialize(self, seqs=None):
        super()._materialize(seqs)
        if seqs is None:
            # full materialize (checkpoint serializer): losses must become
            # host floats for the snapshot
            self._flush_deferred()

    def _materialize_for_commit(self):
        # train only what this commit reads; off-buffer jobs stay queued
        self._materialize({u.seq for u, _ in self._buffer})

    def _commit_host_fetch(self, metrics, ups):
        # THE one host sync of the commit window: delta_norm + every
        # deferred loss bucket in a single device_get
        vals = self._host_fetch({"dn": metrics["delta_norm"],
                                 "lv": [b for b, _ in self._deferred]})
        self._assign_losses(vals["lv"])
        return float(vals["dn"]), [float(u.loss) for u in ups]

    def _do_commit(self, params, server_state, at_time, timeout=False):
        out = super()._do_commit(params, server_state, at_time, timeout)
        # a fresh window begins: reserve the next RNG/GC blocks
        self.backend.begin_window(self.window)
        return out

    # ------------------------------------------------ checkpointable state
    def _after_restore(self):
        # the loader assigned a plain heapified tuple list to _events and
        # rewrote jrng under the key block; deferred buckets were flushed
        # by the pre-save materialize
        super()._after_restore()
        self._events = PendingStore(self._events)
        self._keys.reset()
        self._deferred = []
        self.backend.begin_window(self.window)
