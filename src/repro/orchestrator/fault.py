"""Fault injection (paper §5.3/§5.4: dropouts, spot preemption, partitions).

Synchronous path: faults zero a client's mask entry for the round; the round
step's mask-normalised aggregation (partial aggregation) makes the system
tolerate them — the property Table "Straggler Resilience" measures (20%
dropout -> <1.8% accuracy loss).

Asynchronous path: faults are *typed events with a strike time*.
``draw_fault`` attributes each failure to a cause — plain ``dropout``
(client gone for the attempt), ``preempt`` (spot instance reclaimed
mid-training) or ``partition`` (whole site unreachable) — plus the fraction
of the attempt completed when the fault strikes.  Transient infrastructure
faults (preempt/partition) are recoverable under ``recovery_policy``:

  restart — the client retries the assignment from local step 0 against the
            CURRENT global params (fresh downlink, staleness resets),
  resume  — the client checkpointed locally at its last completed local step
            and re-enqueues with only the remaining work (paper §5.4
            partial-progress recovery; staleness keeps accruing from the
            original dispatch),
  discard — the attempt's work is lost and the slot is freed (the pre-PR-3
            behaviour),
  adaptive — choose restart/resume/discard PER FAULT online from the
            update's observed staleness and remaining work (discard when the
            recovered update would exceed max_staleness anyway); the chosen
            action is logged in ``CommitLog.recovery_actions``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.orchestrator.registry import ClientInfo

RECOVERABLE_FAULTS = ("preempt", "partition")
RECOVERY_POLICIES = ("restart", "resume", "discard", "adaptive")


def equivalent_preempt_rate_per_min(p_attempt: float,
                                    mean_attempt_s: float) -> float:
    """Map ``FaultConfig.spot_preempt_prob`` (per-ATTEMPT Bernoulli) onto the
    memoryless reclaim rate (per minute) of ``K8sAdapter.preempt_prob_per_min``.

    The K8s adapter reclaims a preemptible pod at an exponential
    time-to-preemption with rate ``lam`` per minute, so an attempt holding
    its node for ``d`` seconds is struck with probability
    ``1 - exp(-lam * d / 60)``.  Equating that to the injector's per-attempt
    ``p`` at the fleet's mean attempt duration gives

        lam = -ln(1 - p) * 60 / mean_attempt_s

    which lets ``--exec-backend scheduler`` reproduce injector-era fault
    tables from the same ``--spot-preempt-prob`` knob instead of demanding a
    hand-retuned ``--spot-preempt-per-min``.  Use
    ``straggler.expected_attempt_s`` for ``mean_attempt_s``."""
    if p_attempt <= 0.0:
        return 0.0
    if p_attempt >= 1.0:
        raise ValueError(
            f"spot_preempt_prob must be < 1 to map onto a finite reclaim "
            f"rate, got {p_attempt}")
    if mean_attempt_s <= 0.0:
        raise ValueError(
            f"mean_attempt_s must be positive, got {mean_attempt_s}")
    return float(-np.log1p(-p_attempt) * 60.0 / mean_attempt_s)


@dataclass
class FaultConfig:
    dropout_prob: float = 0.0       # uniform per-round client dropout
    spot_preempt_prob: float = 0.0  # extra dropout for spot instances
    partition_prob: float = 0.0     # whole-site network partition
    partition_len: int = 2          # rounds a partition lasts
    recovery_policy: str = "restart"   # restart|resume|discard|adaptive (async)
    recovery_overhead_s: float = 0.0   # restart/reschedule delay per retry
    max_retries: int = 2               # recovery attempts before giving up

    def __post_init__(self):
        if self.recovery_policy not in RECOVERY_POLICIES:
            raise ValueError(
                f"recovery_policy must be one of {RECOVERY_POLICIES}, got "
                f"{self.recovery_policy!r}")
        if self.max_retries < 0 or self.recovery_overhead_s < 0:
            raise ValueError("max_retries and recovery_overhead_s must be "
                             "non-negative")


class FaultInjector:
    def __init__(self, cfg: FaultConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self._partitioned_site: str | None = None
        self._partition_left = 0

    # ------------------------------------------------- checkpointable state
    def state(self) -> dict:
        return {"rng": self.rng.bit_generator.state,
                "partitioned_site": self._partitioned_site,
                "partition_left": self._partition_left}

    def set_state(self, s: dict):
        self.rng.bit_generator.state = s["rng"]
        self._partitioned_site = s["partitioned_site"]
        self._partition_left = int(s["partition_left"])

    def step_round(self):
        if self._partition_left > 0:
            self._partition_left -= 1
            if self._partition_left == 0:
                self._partitioned_site = None
        elif self.cfg.partition_prob and self.rng.random() < self.cfg.partition_prob:
            self._partitioned_site = "cloud" if self.rng.random() < 0.5 else "hpc"
            self._partition_left = self.cfg.partition_len

    def draw_fault(self, c: ClientInfo,
                   include_preempt: bool = True) -> tuple[bool, str, float]:
        """One attempt's fate: ``(failed, kind, frac_completed_at_strike)``.

        Same total failure probability as one ``survive_mask`` entry —
        dropout folds in (1 - reliability), spot instances additionally risk
        preemption — but the cause is attributed and a strike time drawn so
        the async event stream reflects WHEN the fault lands, not just that
        the attempt was doomed at dispatch.

        ``include_preempt=False`` removes the spot-preemption component:
        used when the execution backend's OWN event stream produces
        preemptions (``SchedulerBackend.handles_preemption``), so the same
        spot instance is not reclaimed by two independent processes."""
        if self._partitioned_site and c.site == self._partitioned_site:
            return True, "partition", float(self.rng.uniform(0.05, 0.95))
        p_drop = 1 - (1 - self.cfg.dropout_prob) * c.profile.reliability
        p_pre = (self.cfg.spot_preempt_prob
                 if c.profile.spot and include_preempt else 0.0)
        u = self.rng.random()
        if u >= 1 - (1 - p_drop) * (1 - p_pre):
            return False, "", 1.0
        kind = "preempt" if (p_pre and u < p_pre) else "dropout"
        return True, kind, float(self.rng.uniform(0.05, 0.95))

    def survive_mask(self, clients: list[ClientInfo],
                     include_preempt: bool = True) -> np.ndarray:
        mask = np.ones(len(clients))
        for i, c in enumerate(clients):
            p = self.cfg.dropout_prob
            if c.profile.spot and include_preempt:
                p = 1 - (1 - p) * (1 - self.cfg.spot_preempt_prob)
            p = 1 - (1 - p) * c.profile.reliability
            if self.rng.random() < p:
                mask[i] = 0.0
            if self._partitioned_site and c.site == self._partitioned_site:
                mask[i] = 0.0
        return mask
