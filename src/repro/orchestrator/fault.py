"""Fault injection (paper §5.3/§5.4: dropouts, spot preemption, partitions).

Faults zero a client's mask entry for the round; the round step's
mask-normalised aggregation (partial aggregation) makes the system tolerate
them — the property Table "Straggler Resilience" measures (20% dropout ->
<1.8% accuracy loss)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.orchestrator.registry import ClientInfo


@dataclass
class FaultConfig:
    dropout_prob: float = 0.0       # uniform per-round client dropout
    spot_preempt_prob: float = 0.0  # extra dropout for spot instances
    partition_prob: float = 0.0     # whole-site network partition
    partition_len: int = 2          # rounds a partition lasts


class FaultInjector:
    def __init__(self, cfg: FaultConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self._partitioned_site: str | None = None
        self._partition_left = 0

    def step_round(self):
        if self._partition_left > 0:
            self._partition_left -= 1
            if self._partition_left == 0:
                self._partitioned_site = None
        elif self.cfg.partition_prob and self.rng.random() < self.cfg.partition_prob:
            self._partitioned_site = "cloud" if self.rng.random() < 0.5 else "hpc"
            self._partition_left = self.cfg.partition_len

    def survive_mask(self, clients: list[ClientInfo]) -> np.ndarray:
        mask = np.ones(len(clients))
        for i, c in enumerate(clients):
            p = self.cfg.dropout_prob
            if c.profile.spot:
                p = 1 - (1 - p) * (1 - self.cfg.spot_preempt_prob)
            p = 1 - (1 - p) * c.profile.reliability
            if self.rng.random() < p:
                mask[i] = 0.0
            if self._partitioned_site and c.site == self._partitioned_site:
                mask[i] = 0.0
        return mask
