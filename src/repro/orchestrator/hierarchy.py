"""Two-tier cross-facility federation (ROADMAP "Hierarchical cross-facility
federation"; cf. "Scalable Cross-Facility Federated Learning on Multiple
Supercomputers" and OmniFed in PAPERS.md).

A ``Facility`` is one self-contained federation site: its own client
sub-fleet, its own ``ExecutionBackend`` (one SLURM pool, one K8s pool, …),
its own per-client data samplers, and a tier-1 aggregator running either
the synchronous barrier loop (``Orchestrator``) or the buffered-async
regime (``AsyncOrchestrator``).  One *epoch* of a facility = ``local_rounds``
tier-1 rounds/commits starting from the tier-2 params snapshot it was
handed; the facility returns the resulting params *delta*.

``HierarchicalOrchestrator`` federates those facility deltas through the
same ``core.pipeline`` stage stack every flat regime uses — the jit'd
buffered commit with staleness discounting and (optionally) commit-keyed
secure-agg masks, so hierarchy composes with fused kernels, adaptive
alpha and the masked wire.  Inter-facility transfers cross the WAN: every
params broadcast / delta upload is charged over ``comm.WANTopology``
(the DCN link class by default, per-pair bandwidth/latency overrides,
optional exponential jitter) and lands in the comm ledger under the
``inter_facility`` direction with the facility index as the cid.

Two inter-facility modes:

  sync  — a tier-2 barrier: every facility runs one epoch against the
          same snapshot, the commit applies all F deltas with staleness 0,
          and the tier-2 clock advances by the slowest facility's
          WAN-down + epoch + WAN-up leg.
  async — FedBuff at facility granularity: facilities run free, deltas
          arrive on a tier-2 event heap, the server commits every
          ``buffer_size`` arrivals discounting by commits-elapsed
          staleness, and a committed-or-dropped facility is immediately
          re-dispatched against the live params.

Determinism/restore contract matches the flat orchestrators: every random
draw flows from seeded generators owned by this object or its facilities,
and ``checkpoint.async_state`` serialises the full two-tier state
(tier-2 heap/buffer/RNGs + each facility's sub-orchestrator) for
bit-identical kill/``--resume`` (tests/test_hierarchy.py).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, replace
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.transport import CommAccountant, WANTopology
from repro.core.async_round import AsyncConfig, build_buffer_commit_step
from repro.core.compression import payload_bytes
from repro.core.round import FLConfig
from repro.core.secure_agg import masked_payload_bytes
from repro.data.federated import FederatedDataset
from repro.optim import get_server_optimizer
from repro.orchestrator.async_server import AsyncOrchestrator, CommitLog
from repro.orchestrator.registry import ClientInfo
from repro.orchestrator.server import Orchestrator

SERVER_NODE = "server"      # the tier-2 hub's name in the WAN topology


@dataclass
class FacilityResult:
    """What one facility epoch hands the tier-2 server."""
    delta: object               # params pytree: p_after_epoch - p_snapshot
    weight: float               # facility data weight (sum of client sizes)
    loss: float                 # last tier-1 round/commit client loss
    wall_s: float               # facility sim-time the epoch consumed


@dataclass
class FacilityUpdate:
    """One facility delta travelling through the tier-2 event queue."""
    seq: int                    # tier-2 dispatch order (heap tie-break)
    fac: int                    # facility index
    dispatch_version: int       # tier-2 commit counter at dispatch
    dispatch_time: float
    wall_s: float               # facility epoch duration
    up_seconds: float           # WAN upload leg (drawn at dispatch)
    weight: float = 1.0
    loss: float = float("nan")
    delta: object = None


class Facility:
    """One federation site: a named sub-orchestrator + its local regime.

    The wrapped orchestrator keeps ITS OWN clock, RNG streams, logs, comm
    ledger and backend across epochs — an async facility's in-flight
    clients carry over from one tier-2 epoch to the next (that is where
    real cross-epoch staleness comes from)."""

    def __init__(self, name: str, orch, local_rounds: int = 1):
        if isinstance(orch, AsyncOrchestrator):
            self.mode = "async"
        elif isinstance(orch, Orchestrator):
            self.mode = "sync"
        else:
            raise TypeError(f"unsupported facility orchestrator {type(orch)}")
        if orch.checkpoint_mgr is not None:
            raise ValueError(
                "facility orchestrators must not own a checkpoint manager; "
                "hierarchy state is snapshotted by the tier-2 server")
        self.name = name
        self.orch = orch
        self.local_rounds = int(local_rounds)

    @property
    def clock(self) -> float:
        return (self.orch.clock if self.mode == "async"
                else self.orch.virtual_clock)

    def data_weight(self) -> float:
        return float(sum(max(c.data_size, 1) for c in self.orch.fleet))

    def run_epoch(self, params) -> FacilityResult:
        """Run ``local_rounds`` tier-1 rounds/commits from ``params``.

        Tier-1 server-optimizer state is fresh per epoch: the facility
        aggregates *within* the epoch, while cross-epoch momentum belongs
        to the tier-2 server optimizer."""
        t0 = self.clock
        server_state = self.orch.init_server_state(params)
        if self.mode == "sync":
            p = params
            for _ in range(self.local_rounds):
                rnd = len(self.orch.logs)
                p, server_state, _ = self.orch.run_round(rnd, p, server_state)
        else:
            p, _ = self.orch.run(params, self.orch.version + self.local_rounds,
                                 server_state=server_state)
        delta = jax.tree.map(lambda a, b: a - b, p, params)
        loss = (self.orch.logs[-1].client_loss if self.orch.logs
                else float("nan"))
        return FacilityResult(delta=delta, weight=self.data_weight(),
                              loss=loss, wall_s=self.clock - t0)


class HierarchicalOrchestrator:
    """Tier-2 server federating facility deltas over modeled WAN links."""

    def __init__(self, facilities: list[Facility], fl: FLConfig,
                 inter_mode: str = "sync",
                 async_cfg: AsyncConfig | None = None,
                 wan: WANTopology | None = None,
                 server_opt_name: str = "fedavg",
                 server_opt_kw: dict | None = None,
                 eval_fn: Optional[Callable] = None, eval_every: int = 1,
                 checkpoint_mgr=None, checkpoint_every: int = 0,
                 seed: int = 0):
        if inter_mode not in ("sync", "async"):
            raise ValueError(f"inter_mode must be sync|async, got {inter_mode!r}")
        if not facilities:
            raise ValueError("need at least one facility")
        self.facilities = facilities
        self.fl = fl
        self.inter_mode = inter_mode
        if async_cfg is None:
            async_cfg = AsyncConfig(buffer_size=1)
        if inter_mode == "sync":
            # the tier-2 barrier commits exactly one delta per facility
            async_cfg = replace(async_cfg, buffer_size=len(facilities))
        self.async_cfg = async_cfg
        self.wan = wan if wan is not None else WANTopology()
        self.eval_fn, self.eval_every = eval_fn, eval_every
        self.checkpoint_mgr = checkpoint_mgr
        self.checkpoint_every = checkpoint_every
        self.seed = seed
        self.rng = np.random.default_rng(seed)      # WAN jitter stream
        self.jrng = jax.random.PRNGKey(seed)        # tier-2 commit rng
        self.comm = CommAccountant()                # inter-facility ledger
        self.logs: list[CommitLog] = []
        server_opt = get_server_optimizer(server_opt_name,
                                          **(server_opt_kw or {}))
        self._server_opt = server_opt
        self._commit_step = jax.jit(build_buffer_commit_step(
            server_opt, fl, self.async_cfg))
        self._alpha = self.async_cfg.initial_exponent()
        self.clock = 0.0
        self.version = 0            # tier-2 commit counter
        self.dropped_stale = 0
        self._seq = 0
        self._events: list = []     # heap of (arrival, seq, FacilityUpdate)
        self._buffer: list[tuple] = []   # [(FacilityUpdate, arrival_time)]
        self._buffer_bytes = 0

    # ------------------------------------------------------------------
    def init_server_state(self, params):
        return self._server_opt.init(params)

    def _payload_bytes_cache(self, params):
        """(down, up) WAN bytes one facility leg costs: params broadcast
        down, the (masked, under secure_agg) facility delta up."""
        if not hasattr(self, "_pb"):
            down = payload_bytes(params, self.fl.compression)
            up = (masked_payload_bytes(params, self.fl.compression,
                                       n_slots=self.async_cfg.buffer_size)
                  if self.fl.secure_agg else down)
            self._pb = (down, up)
        return self._pb

    def _wan_seconds(self, a: str, b: str, nbytes: int) -> float:
        return self.wan.transfer_time(a, b, nbytes, rng=self.rng)

    # --------------------------------------------------------------- tier 2
    def _dispatch(self, fac_idx: int, params, now: float) -> FacilityUpdate:
        """Broadcast params to one facility, run its epoch eagerly, and
        price both WAN legs.  The upload leg is drawn now (so the WAN
        jitter stream stays in dispatch order) but logged at arrival."""
        fac = self.facilities[fac_idx]
        down_b, up_b = self._payload_bytes_cache(params)
        down_s = self._wan_seconds(SERVER_NODE, fac.name, down_b)
        self.comm.log(self.version, fac_idx, "inter_facility", down_b,
                      self.wan.link(SERVER_NODE, fac.name), seconds=down_s)
        res = fac.run_epoch(params)
        up_s = self._wan_seconds(fac.name, SERVER_NODE, up_b)
        upd = FacilityUpdate(seq=self._seq, fac=fac_idx,
                             dispatch_version=self.version,
                             dispatch_time=now, wall_s=res.wall_s,
                             up_seconds=up_s, weight=res.weight,
                             loss=res.loss, delta=res.delta)
        self._seq += 1
        heapq.heappush(self._events,
                       (now + down_s + res.wall_s + up_s, upd.seq, upd))
        return upd

    def _log_arrival(self, upd: FacilityUpdate, params):
        up_b = self._payload_bytes_cache(params)[1]
        fac = self.facilities[upd.fac]
        self.comm.log(self.version, upd.fac, "inter_facility", up_b,
                      self.wan.link(fac.name, SERVER_NODE),
                      seconds=upd.up_seconds)
        return up_b

    def _commit(self, params, server_state, at_time: float):
        """One tier-2 commit over the buffered facility deltas, through the
        same jit'd pipeline commit the flat async regime uses (compress →
        staleness discount → secure mask → aggregate → normalise)."""
        K = self.async_cfg.buffer_size
        ups = [u for u, _ in self._buffer]
        stal = [self.version - u.dispatch_version for u in ups]
        pad = K - len(ups)
        zero = jax.tree.map(jnp.zeros_like, ups[0].delta)
        deltas = [u.delta for u in ups] + [zero] * pad
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        weights = jnp.asarray([u.weight for u in ups] + [0.0] * pad,
                              jnp.float32)
        staleness = jnp.asarray(stal + [0] * pad, jnp.float32)
        losses = jnp.asarray([u.loss for u in ups] + [0.0] * pad, jnp.float32)
        mask = jnp.asarray([1.0] * len(ups) + [0.0] * pad, jnp.float32)
        ids = jnp.arange(K, dtype=jnp.int32)
        self.jrng, r = jax.random.split(self.jrng)
        params, server_state, metrics = self._commit_step(
            params, server_state, stacked, weights, staleness, losses,
            mask, ids, jnp.float32(self._alpha), r)
        self.version += 1
        losses_f = [u.loss for u in ups if np.isfinite(u.loss)]
        log = CommitLog(
            commit=self.version, sim_time=at_time, n_updates=len(ups),
            mean_staleness=float(np.mean(stal)) if stal else 0.0,
            max_staleness=int(max(stal)) if stal else 0,
            client_loss=float(np.mean(losses_f)) if losses_f else float("nan"),
            delta_norm=float(metrics["delta_norm"]),
            bytes_up=self._buffer_bytes,
            staleness_alpha=self._alpha,
            inter_facility_bytes=self._buffer_bytes)
        if self.eval_fn and (self.version % self.eval_every == 0):
            log.eval_metric = float(self.eval_fn(params))
        self.logs.append(log)
        self._buffer = []
        self._buffer_bytes = 0
        return params, server_state

    # ------------------------------------------------------------------ run
    def save_checkpoint(self, params, server_state):
        if self.checkpoint_mgr is None:
            raise ValueError("no checkpoint_mgr configured")
        self.checkpoint_mgr.save_hier(self, params, server_state)

    def _maybe_checkpoint(self, params, server_state, last_ckpt: int) -> int:
        if (self.checkpoint_mgr and self.checkpoint_every
                and self.version != last_ckpt
                and self.version % self.checkpoint_every == 0):
            self.save_checkpoint(params, server_state)
            return self.version
        return last_ckpt

    def run(self, params, num_commits: int, server_state=None,
            verbose: bool = False):
        """Run until ``num_commits`` tier-2 commits (epochs, in sync mode)."""
        if server_state is None:
            server_state = self.init_server_state(params)
        if self.inter_mode == "sync":
            params, server_state = self._run_sync(params, server_state,
                                                  num_commits, verbose)
        else:
            params, server_state = self._run_async(params, server_state,
                                                   num_commits, verbose)
        if self.checkpoint_mgr is not None:
            self.save_checkpoint(params, server_state)
        if self.eval_fn and self.logs and not np.isfinite(
                self.logs[-1].eval_metric):
            self.logs[-1].eval_metric = float(self.eval_fn(params))
        return params, server_state

    def _run_sync(self, params, server_state, num_commits, verbose):
        last_ckpt = self.version
        for _ in range(self.version, num_commits):
            now = self.clock
            legs = []
            for i in range(len(self.facilities)):
                self._dispatch(i, params, now)
            # the barrier: drain every arrival this epoch produced
            while self._events:
                t, _, upd = heapq.heappop(self._events)
                legs.append(t - now)
                up_b = self._log_arrival(upd, params)
                self._buffer.append((upd, t))
                self._buffer_bytes += up_b
            self.clock = now + max(legs)
            params, server_state = self._commit(params, server_state,
                                                self.clock)
            if verbose and self.logs:
                lg = self.logs[-1]
                print(f"t2-epoch {lg.commit:4d} t={lg.sim_time:9.1f}s "
                      f"loss={lg.client_loss:.4f} "
                      f"wan_B={lg.inter_facility_bytes} "
                      f"eval={lg.eval_metric:.4f}")
            last_ckpt = self._maybe_checkpoint(params, server_state,
                                               last_ckpt)
        return params, server_state

    def _run_async(self, params, server_state, num_commits, verbose):
        if not self._events:
            for i in range(len(self.facilities)):
                self._dispatch(i, params, self.clock)
        last_ckpt = self.version
        while self._events and self.version < num_commits:
            t, seq, upd = heapq.heappop(self._events)
            self.clock = max(self.clock, t)
            up_b = self._log_arrival(upd, params)
            staleness = self.version - upd.dispatch_version
            if staleness > self.async_cfg.max_staleness:
                self.dropped_stale += 1
            else:
                self._buffer.append((upd, t))
                self._buffer_bytes += up_b
            if len(self._buffer) >= self.async_cfg.buffer_size:
                params, server_state = self._commit(params, server_state, t)
                if verbose and self.logs:
                    lg = self.logs[-1]
                    print(f"t2-commit {lg.commit:4d} t={lg.sim_time:9.1f}s "
                          f"loss={lg.client_loss:.4f} "
                          f"stale={lg.mean_staleness:.1f} "
                          f"eval={lg.eval_metric:.4f}")
            # the facility is free again: hand it the live params
            self._dispatch(upd.fac, params, self.clock)
            last_ckpt = self._maybe_checkpoint(params, server_state,
                                               last_ckpt)
        return params, server_state

    # ------------------------------------------------------------- metrics
    @property
    def inter_facility_bytes(self) -> int:
        return sum(r.nbytes for r in self.comm.records
                   if r.direction == "inter_facility")

    def total_bytes(self) -> int:
        """WAN bytes + every facility's intra-site ledger."""
        return self.inter_facility_bytes + sum(
            f.orch.comm.total_bytes() for f in self.facilities)


# ----------------------------------------------------------------- builders
def split_fleet(fleet: list[ClientInfo], n_facilities: int):
    """Contiguous near-equal split into per-facility sub-fleets.

    Sub-fleet clients get LOCAL cids (0..n_f-1) so each facility is exactly
    a flat federation over its own fleet — selection, checkpoint and data
    indexing inside a facility all keep the cid == index invariant the flat
    orchestrators assume.  Profiles are shared by reference (never mutated);
    histories are per-facility copies."""
    if not 1 <= n_facilities <= len(fleet):
        raise ValueError(f"cannot split {len(fleet)} clients into "
                         f"{n_facilities} facilities")
    bounds = np.linspace(0, len(fleet), n_facilities + 1).astype(int)
    subs, ranges = [], []
    for f in range(n_facilities):
        lo, hi = int(bounds[f]), int(bounds[f + 1])
        subs.append([ClientInfo(cid=i, site=c.site, profile=c.profile,
                                data_size=c.data_size)
                     for i, c in enumerate(fleet[lo:hi])])
        ranges.append((lo, hi))
    return subs, ranges


def make_facilities(n_facilities: int, fleet: list[ClientInfo],
                    fed_data: FederatedDataset, loss_fn: Callable,
                    fl: FLConfig, *, local_mode: str = "sync",
                    async_cfg: AsyncConfig | None = None,
                    local_rounds: int = 1, backend_factory=None,
                    seed: int = 0, orch_kw: dict | None = None
                    ) -> list[Facility]:
    """Build N facilities over a contiguous split of ``fleet``/``fed_data``.

    Facility f runs ``local_mode`` over its sub-fleet with its own
    ``FederatedDataset`` view (same underlying data, its slice of the
    client shards) and its own backend (``backend_factory(f)``; None →
    each facility gets a private closed-form backend).  Seeds are offset
    per facility EXCEPT facility 0, which keeps the caller's seeds so the
    degenerate 1-facility hierarchy reproduces the flat federation
    (tests/test_hierarchy.py pins this to 1e-6)."""
    subs, ranges = split_fleet(fleet, n_facilities)
    orch_kw = dict(orch_kw or {})
    facs = []
    for f, (sub, (lo, hi)) in enumerate(zip(subs, ranges)):
        fed_f = FederatedDataset(fed_data.data,
                                 list(fed_data.client_indices[lo:hi]),
                                 seed=fed_data.seed + 7919 * f)
        fl_f = replace(fl, mode=local_mode,
                       num_clients=min(fl.num_clients, len(sub)))
        seed_f = seed + 1000 * f
        backend = backend_factory(f) if backend_factory else None
        if local_mode == "sync":
            orch = Orchestrator(fleet=sub, fed_data=fed_f, loss_fn=loss_fn,
                                fl=fl_f, backend=backend, seed=seed_f,
                                **orch_kw)
        else:
            orch = AsyncOrchestrator(fleet=sub, fed_data=fed_f,
                                     loss_fn=loss_fn, fl=fl_f,
                                     async_cfg=async_cfg or AsyncConfig(),
                                     backend=backend, seed=seed_f, **orch_kw)
        facs.append(Facility(name=f"fac{f}", orch=orch,
                             local_rounds=local_rounds))
    return facs
