"""Client selection strategies (paper §4.1 Adaptive Client Selection)."""
from __future__ import annotations

import numpy as np

from repro.orchestrator.registry import ClientInfo


class RandomSelection:
    """Uniform sampling (the FedAvg default; the paper's ablation baseline)."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, fleet: list[ClientInfo], k: int, rnd: int) -> list[int]:
        avail = [c.cid for c in fleet]
        return list(self.rng.choice(avail, min(k, len(avail)), replace=False))


class AdaptiveSelection:
    """Scores clients by resource profile x history, with load balancing and
    a fairness/aging term (so slow-but-unique data still participates).

      score = compute^a * bandwidth^b * success_rate^c * aging
    Load balancing: the slowest `exclude_frac` quantile (by EMA round time)
    is temporarily excluded (paper: "underperforming or slower nodes may be
    temporarily excluded")."""

    def __init__(self, seed: int = 0, exclude_frac: float = 0.2,
                 a: float = 0.5, b: float = 0.3, c: float = 2.0,
                 aging_boost: float = 0.15, softmax_temp: float = 1.0):
        self.rng = np.random.default_rng(seed)
        self.exclude_frac = exclude_frac
        self.a, self.b, self.c = a, b, c
        self.aging_boost = aging_boost
        self.temp = softmax_temp

    def select(self, fleet: list[ClientInfo], k: int, rnd: int) -> list[int]:
        """One vectorised numpy scoring pass over the candidate arrays.

        The original per-client Python loop (a pow/log call per client per
        dispatch) was the profile-confirmed reason the legacy async engine
        died at 10k clients; the field gather stays O(population) but the
        arithmetic is a handful of array ops.  Probabilities are computed
        with the exact expression structure of the scalar loop so the
        rng.choice draw — and therefore every selection trajectory — is
        bitwise unchanged (pinned in tests/test_orchestrator.py)."""
        cands = list(fleet)
        ema = np.fromiter((c.ema_round_time for c in cands), np.float64,
                          len(cands))
        # load balancing: drop the slowest quantile among profiled clients
        timed = ema > 0
        if int(timed.sum()) > 4 and self.exclude_frac:
            cutoff = np.quantile(ema[timed], 1.0 - self.exclude_frac)
            keep = ~(timed & (ema > cutoff))
            if int(keep.sum()) >= k:
                cands = [c for c, m in zip(cands, keep) if m]
        ct = np.fromiter((c.profile.compute_tflops for c in cands),
                         np.float64, len(cands))
        bw = np.fromiter((c.profile.bandwidth_gbps for c in cands),
                         np.float64, len(cands))
        sr = np.fromiter((c.success_rate for c in cands), np.float64,
                         len(cands))
        last = np.fromiter((c.last_selected_round for c in cands),
                           np.float64, len(cands))
        scores = (np.maximum(ct, 1e-3) ** self.a
                  * np.maximum(bw, 1e-3) ** self.b
                  * np.maximum(sr, 0.05) ** self.c)
        scores = scores * (1.0 + self.aging_boost
                           * np.log1p(np.maximum(rnd - last, 0.0)))
        p = np.exp(np.log(scores + 1e-12) / self.temp)
        p /= p.sum()
        pick = self.rng.choice([c.cid for c in cands], min(k, len(cands)),
                               replace=False, p=p)
        return list(pick)


def get_selection(name: str, **kw):
    return {"random": RandomSelection, "adaptive": AdaptiveSelection}[name](**kw)
