"""Client selection strategies (paper §4.1 Adaptive Client Selection)."""
from __future__ import annotations

import numpy as np

from repro.orchestrator.registry import ClientInfo


class RandomSelection:
    """Uniform sampling (the FedAvg default; the paper's ablation baseline)."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, fleet: list[ClientInfo], k: int, rnd: int) -> list[int]:
        avail = [c.cid for c in fleet]
        return list(self.rng.choice(avail, min(k, len(avail)), replace=False))


class AdaptiveSelection:
    """Scores clients by resource profile x history, with load balancing and
    a fairness/aging term (so slow-but-unique data still participates).

      score = compute^a * bandwidth^b * success_rate^c * aging
    Load balancing: the slowest `exclude_frac` quantile (by EMA round time)
    is temporarily excluded (paper: "underperforming or slower nodes may be
    temporarily excluded")."""

    def __init__(self, seed: int = 0, exclude_frac: float = 0.2,
                 a: float = 0.5, b: float = 0.3, c: float = 2.0,
                 aging_boost: float = 0.15, softmax_temp: float = 1.0):
        self.rng = np.random.default_rng(seed)
        self.exclude_frac = exclude_frac
        self.a, self.b, self.c = a, b, c
        self.aging_boost = aging_boost
        self.temp = softmax_temp

    def select(self, fleet: list[ClientInfo], k: int, rnd: int) -> list[int]:
        cands = list(fleet)
        # load balancing: drop the slowest quantile among profiled clients
        timed = [c for c in cands if c.ema_round_time > 0]
        if len(timed) > 4 and self.exclude_frac:
            cutoff = np.quantile([c.ema_round_time for c in timed],
                                 1.0 - self.exclude_frac)
            slow = {c.cid for c in timed if c.ema_round_time > cutoff}
            kept = [c for c in cands if c.cid not in slow]
            if len(kept) >= k:
                cands = kept
        scores = []
        for c in cands:
            s = (max(c.profile.compute_tflops, 1e-3) ** self.a
                 * max(c.profile.bandwidth_gbps, 1e-3) ** self.b
                 * max(c.success_rate, 0.05) ** self.c)
            age = rnd - c.last_selected_round
            s *= 1.0 + self.aging_boost * np.log1p(max(age, 0))
            scores.append(s)
        scores = np.asarray(scores, np.float64)
        p = np.exp(np.log(scores + 1e-12) / self.temp)
        p /= p.sum()
        pick = self.rng.choice([c.cid for c in cands], min(k, len(cands)),
                               replace=False, p=p)
        return list(pick)


def get_selection(name: str, **kw):
    return {"random": RandomSelection, "adaptive": AdaptiveSelection}[name](**kw)
