"""Event-driven asynchronous orchestrator (FedBuff execution regime).

Replaces the per-round barrier of ``Orchestrator`` with a simulated event
queue: up to ``max_concurrency`` clients train concurrently, each against
the params snapshot current at its dispatch; finish times come from the
pluggable ``ExecutionBackend`` (``repro.exec``) — closed-form heterogeneous
profiles + lognormal contention noise by default, or the SLURM/K8s
scheduler simulation (queue waits, elastic overflow, adapter-origin spot
preemptions) — so fast HPC nodes lap slow cloud VMs instead of waiting.
Updates land in a bounded buffer; the server commits every K arrivals or
after ``commit_timeout_s`` sim-seconds of buffered quiet, discounting each
update by its staleness (commits elapsed since dispatch).

Host-side only, deterministic under a fixed seed: the heap is ordered by
(arrival_time, dispatch_seq) and every random draw flows from the seeded
generators.  The heavy math is the pair of jit'd steps from
repro.core.async_round; per-update bytes/time cross the CommAccountant
exactly as in the sync orchestrator (down at dispatch, up at arrival).
"""
from __future__ import annotations

import heapq
from contextlib import contextmanager
from dataclasses import dataclass, field
from time import perf_counter
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.transport import CommAccountant, link_for_site
from repro.core.async_round import (AdaptiveStalenessController, AsyncConfig,
                                    build_buffer_commit_step,
                                    build_chunked_commit_steps,
                                    build_client_update_step)
from repro.core.compression import payload_bytes
from repro.core.secure_agg import masked_payload_bytes
from repro.core.round import FLConfig
from repro.optim import get_client_optimizer, get_server_optimizer
from repro.orchestrator.fault import (RECOVERABLE_FAULTS, FaultConfig,
                                      FaultInjector)
from repro.orchestrator.selection import get_selection
from repro.orchestrator.straggler import StragglerPolicy


@dataclass
class PendingUpdate:
    """One in-flight client update travelling through the event queue."""
    seq: int                    # dispatch order (heap tie-break)
    cid: int
    client_idx: int             # index into the fleet list
    dispatch_version: int       # server commit counter at dispatch
    dispatch_time: float
    duration_s: float           # fault-free attempt duration (recovery base)
    delta: object = None        # pytree (None if the client faulted)
    loss: float = float("nan")
    weight: float = 1.0
    failed: bool = False
    fault: str = ""             # dropout | preempt | partition ("" = none)
    steps_done: int = 0         # local steps checkpointed before the fault
    retries: int = 0            # recovery attempts consumed so far
    recovery_s: float = 0.0     # arrival delay vs. the fault-free attempt
    work_s: float = 0.0         # closed-form work (scheduler: sans queue)
    queue_wait_s: float = 0.0   # time spent queued before the node started
    site: str = ""              # placement site the attempt ran on
    job_id: str = ""            # scheduler-backend job backing the attempt


@dataclass
class CommitLog:
    commit: int
    sim_time: float
    n_updates: int
    mean_staleness: float
    max_staleness: int
    client_loss: float
    delta_norm: float
    bytes_up: int
    timeout_commit: bool = False
    eval_metric: float = float("nan")
    n_recovered: int = 0               # committed updates that survived a fault
    recovery_time_s: float = 0.0       # mean extra latency those updates paid
    staleness_alpha: float = 0.5       # discount exponent used BY this commit
    mask_overhead_bytes: int = 0       # uplink bytes masking added over the
    #                                    plain (compressed) wire payload
    queue_wait_s: float = 0.0          # mean scheduler queue wait of the
    #                                    committed updates (scheduler backend)
    n_overflow: int = 0                # committed updates that ran off their
    #                                    home site (elastic HPC->cloud burst)
    inter_facility_bytes: int = 0      # WAN bytes (dcn link) the committed
    #                                    facility deltas paid — hierarchy
    #                                    tier-2 commits only, 0 in flat runs
    recovery_actions: list = field(default_factory=list)
    #                                  # "fault:policy" decisions the adaptive
    #                                    recovery policy took since the
    #                                    previous commit
    phase_wall: dict = field(default_factory=dict)
    #                                  # host wall-clock seconds spent per
    #                                    engine phase (dispatch/train/commit/
    #                                    host_sync) plus the host-sync count
    #                                    since the previous commit.  Profiling
    #                                    only — excluded from every trajectory
    #                                    equivalence comparison.


@dataclass
class AsyncOrchestrator:
    fleet: list                       # list[ClientInfo]
    fed_data: object                  # FederatedDataset
    loss_fn: Callable                 # (params, batch) -> (loss, aux)
    fl: FLConfig
    async_cfg: AsyncConfig = field(default_factory=AsyncConfig)
    client_opt_name: str = "sgd"
    server_opt_name: str = "fedavg"
    server_opt_kw: dict = field(default_factory=dict)
    selection_name: str = "adaptive"
    straggler: StragglerPolicy = field(default_factory=StragglerPolicy)
    faults: FaultConfig = field(default_factory=FaultConfig)
    batch_size: int = 16
    flops_per_client_round: float = 1e12
    eval_fn: Optional[Callable] = None     # (params) -> float metric
    eval_every: int = 10                   # in commits
    checkpoint_mgr: object = None          # AsyncCheckpointManager (or None)
    checkpoint_every: int = 0              # in commits (0 = only at run end)
    backend: object = None                 # ExecutionBackend (None -> closed)
    seed: int = 0

    def __post_init__(self):
        if self.fl.mode != "async":
            raise ValueError(
                f"AsyncOrchestrator requires FLConfig(mode='async'), got "
                f"mode={self.fl.mode!r}; use Orchestrator for the "
                f"synchronous barrier loop")
        self.rng = np.random.default_rng(self.seed)
        self.jrng = jax.random.PRNGKey(self.seed)
        if self.backend is None:
            # local import: repro.exec consumes the straggler model from
            # this package, so a module-level import would be circular
            from repro.exec.backend import ClosedFormBackend
            self.backend = ClosedFormBackend()
        self.backend.bind(self.rng, self.straggler)
        self.selection = get_selection(self.selection_name, seed=self.seed)
        self.fault_injector = FaultInjector(self.faults, seed=self.seed + 1)
        self.comm = CommAccountant()
        self.logs: list[CommitLog] = []
        client_opt = get_client_optimizer(self.client_opt_name)
        server_opt = get_server_optimizer(self.server_opt_name,
                                          **self.server_opt_kw)
        self._server_opt = server_opt
        self._client_update = jax.jit(build_client_update_step(
            self.loss_fn, client_opt, self.fl))
        self._commit_step = jax.jit(build_buffer_commit_step(
            server_opt, self.fl, self.async_cfg))
        # chunked commit: accumulate the buffer C slots at a time (one
        # device call per chunk) and normalise/apply once.  Only engaged
        # when the chunk is smaller than the buffer — otherwise the
        # single-shot step is strictly better (and bit-identical to the
        # pre-chunk behaviour).
        self._chunk_steps = None
        if 0 < self.async_cfg.commit_chunk < self.async_cfg.buffer_size:
            acc_step, fin_step = build_chunked_commit_steps(
                server_opt, self.fl, self.async_cfg)
            self._chunk_steps = (jax.jit(acc_step), jax.jit(fin_step))
        # staleness exponent: a constant, or an online controller whose alpha
        # feeds the jit'd commit step as a runtime scalar (no recompiles)
        self._staleness_ctrl = (AdaptiveStalenessController()
                                if self.async_cfg.adaptive_staleness else None)
        self._alpha = self.async_cfg.initial_exponent()
        # simulation state
        self.clock = 0.0
        self.version = 0              # server commit counter
        self.updates_applied = 0      # accepted client updates committed
        self.dropped_stale = 0
        self.recovered_updates = 0    # updates that arrived after >=1 fault
        self.lost_to_faults = 0       # attempts abandoned (no recovery)
        self.recovery_time_total = 0.0
        self._seq = 0
        self._recovery_actions: list[str] = []  # adaptive-policy decisions
        #                               accrued since the last commit
        self._events: list = []       # heap of (arrival_time, seq, PendingUpdate)
        self._inflight: set[int] = set()   # cids currently training
        self._buffer: list[tuple] = []     # [(PendingUpdate, arrival_time)]
        self._buffer_bytes = 0
        # array mirror of the buffered arrival times: the timeout-flush hot
        # path tests its head in O(1) instead of scanning the buffer
        self._buffer_t = np.empty(0)
        # per-phase host wall-clock accounting, flushed into each CommitLog
        self._phase = {"dispatch": 0.0, "train": 0.0, "commit": 0.0,
                       "host_sync": 0.0}
        self._host_syncs = 0
        # processed-event trace: (t, seq, cid, failed, fault) per heap pop —
        # what the resume-equivalence tests pin event ordering against
        self.events_processed: list[tuple] = []

    # ------------------------------------------------------------------
    def init_server_state(self, params):
        return self._server_opt.init(params)

    def _payload_bytes_cache(self, params):
        """(down_bytes, up_bytes) one dispatch/arrival costs on the wire.

        Downlink is the (compressed) params broadcast.  Uplink is the
        client's update: under secure_agg the masked wire size is what both
        the comm ledger and the simulated transfer time are charged.
        Without quantization the additive masks are dense f32; WITH
        quantization masking happens in the quantized integer domain
        (core.pipeline), so the slot ships finite-ring words of
        quantize_bits + ceil(log2(buffer_size)) bits instead."""
        if not hasattr(self, "_pb"):
            down = payload_bytes(params, self.fl.compression)
            up = (masked_payload_bytes(params, self.fl.compression,
                                       n_slots=self.async_cfg.buffer_size)
                  if self.fl.secure_agg else down)
            self._pb = (down, up)
        return self._pb

    # --------------------------------------------------------- phase timers
    @contextmanager
    def _timed(self, phase: str):
        """Attribute elapsed host wall-clock to ``phase``.  Nested phases
        (a host_sync inside train, train inside dispatch) book their own
        time; the outer phase gets elapsed minus whatever inner phases
        accrued, so the four counters partition the wall clock."""
        snap = dict(self._phase)
        t0 = perf_counter()
        try:
            yield
        finally:
            inner = sum(self._phase[k] - snap[k] for k in snap)
            self._phase[phase] += perf_counter() - t0 - inner

    def _host_fetch(self, x):
        """Device->host transfer, counted and billed to the host_sync phase.
        Every engine sync point routes through here so the per-commit
        ``phase_wall['host_syncs']`` counter is trustworthy."""
        with self._timed("host_sync"):
            self._host_syncs += 1
            return jax.device_get(x)

    # ---------------------------------------------------- engine extension
    # The event-window engine (orchestrator.eventwindow) substitutes the
    # structures behind these four seams; the per-event baseline keeps the
    # plain heapq / sequential jax.random.split semantics they wrap.
    def _next_key(self):
        """Advance the jax key chain one split; return the subkey."""
        self.jrng, r = jax.random.split(self.jrng)
        return r

    def _push_event(self, t: float, seq: int, upd: PendingUpdate):
        heapq.heappush(self._events, (t, seq, upd))

    def _pop_event(self):
        return heapq.heappop(self._events)

    def _abandon_update(self, upd: PendingUpdate):
        """``upd`` will never be committed (dropped as stale, or lost to an
        unrecovered fault): engines that defer work for it may cancel the
        pending job.  No-op in the eager per-event engine."""

    # ------------------------------------------------------------- dispatch
    def _train_client(self, upd: PendingUpdate, client, params):
        """Run the client's local training against the given params snapshot."""
        batches = self.fed_data.sample_round([client.cid],
                                             self.fl.local_steps,
                                             self.batch_size)
        batches = jax.tree.map(lambda x: jnp.asarray(x[0]), batches)
        r = self._next_key()
        with self._timed("train"):
            delta, loss = self._client_update(params, batches, r)
            upd.delta = delta
            upd.loss = float(self._host_fetch(loss))
        upd.weight = float(max(self.fed_data.client_size(client.cid), 1))

    def _pick_client(self, rnd: int):
        """Select one idle client: (client_idx, client), or None when every
        client is in flight.  ``rnd`` is the dispatch counter the selection
        strategy scores aging against (the seq the dispatch will get)."""
        avail = [c for c in self.fleet if c.cid not in self._inflight]
        if not avail:
            return None
        sel = self.selection.select(avail, 1, rnd)
        client_idx = next(i for i, c in enumerate(self.fleet)
                          if c.cid == sel[0])
        return client_idx, self.fleet[client_idx]

    def _execute_attempt(self, client, params, now: float):
        """Price one attempt through the execution backend."""
        up_bytes = self._payload_bytes_cache(params)[1]
        return self.backend.execute(client, self.flops_per_client_round,
                                    up_bytes, now)

    def _draw_attempt_fault(self, client):
        # the injector's round clock advances per COMMIT (the async analogue
        # of a round, in _do_commit) so FaultConfig partition probabilities /
        # durations keep their sync-round units; the fault dice — cause and
        # strike time included — roll per dispatch.  When the backend's own
        # event stream produces spot preemptions, the injector must not also
        # reclaim the instance.
        return self.fault_injector.draw_fault(
            client, include_preempt=not self.backend.handles_preemption)

    def _dispatch_one(self, params, now: float):
        """Hand the current params to one idle client; schedule its arrival."""
        with self._timed("dispatch"):
            picked = self._pick_client(self._seq)
            if picked is None:
                return False
            client_idx, client = picked
            ex = self._execute_attempt(client, params, now)
            self._finish_dispatch(client_idx, client, ex, params, now)
        return True

    def _finish_dispatch(self, client_idx, client, ex, params, now: float):
        """Everything after the attempt is priced: fault dice, optional
        local training, comm ledger, and the arrival event."""
        down_bytes, up_bytes = self._payload_bytes_cache(params)
        failed, fault, frac = self._draw_attempt_fault(client)

        upd = PendingUpdate(seq=self._seq, cid=client.cid,
                            client_idx=client_idx,
                            dispatch_version=self.version,
                            dispatch_time=now, duration_s=ex.fault_free_s,
                            failed=failed, fault=fault, work_s=ex.work_s,
                            queue_wait_s=ex.queue_wait_s, site=ex.site,
                            job_id=ex.job_id)
        arrival = now + ex.fault_free_s
        if failed:
            # the injector fault strikes at frac of the attempt's node time:
            # the event stream sees the failure WHEN it happens, not after a
            # phantom full attempt (queue wait has already been paid)
            arrival = now + ex.queue_wait_s + frac * ex.full_run_s
            upd.steps_done = int(frac * self.fl.local_steps)
        elif ex.preempted:
            # scheduler-origin spot reclaim: the strike time comes from the
            # K8s adapter's event stream, not an injector dice roll
            upd.failed, upd.fault = True, "preempt"
            arrival = now + ex.duration_s
            upd.steps_done = int(ex.frac_done * self.fl.local_steps)
        if (not upd.failed) or (upd.fault in RECOVERABLE_FAULTS
                                and self.faults.recovery_policy
                                in ("resume", "adaptive")):
            # the client trains against the params snapshot it is handed NOW;
            # staleness accrues from commits landing while it runs.  Under
            # the resume policy a preempted/partitioned client keeps a local
            # step checkpoint, so its delta (still vs. this snapshot) is
            # computed up front and survives the fault.
            self._train_client(upd, client, params)
        link = link_for_site(ex.site or client.site)
        self.comm.log(self.version, client.cid, "down", down_bytes, link)
        self._inflight.add(client.cid)
        self._push_event(arrival, self._seq, upd)
        self._seq += 1

    def _top_up(self, params):
        """Dispatch until max_concurrency clients are in flight (a
        continuation or restored run may already have some)."""
        target = min(self.async_cfg.max_concurrency, len(self.fleet))
        for _ in range(max(0, target - len(self._inflight))):
            self._dispatch_one(params, self.clock)

    # ------------------------------------------------------------- recovery
    def _choose_recovery(self, upd: PendingUpdate, t: float) -> str:
        """Adaptive per-fault policy: pick restart/resume/discard online from
        the update's observed staleness and its remaining work.

        * discard — the recovered update would exceed ``max_staleness``
          anyway (already stale, or projected to be stale by the time the
          remaining work lands at the observed commit rate);
        * resume  — most of the work is already checkpointed locally, so
          finishing it is cheaper than a fresh attempt;
        * restart — most of the attempt is lost; retrying against the
          CURRENT params also resets the accrued staleness."""
        L = max(self.fl.local_steps, 1)
        remaining_frac = (L - upd.steps_done) / L
        base = upd.work_s or upd.duration_s
        remaining_s = (base * remaining_frac
                       + self.faults.recovery_overhead_s)
        staleness_now = self.version - upd.dispatch_version
        commit_rate = self.version / self.clock if self.clock > 0 else 0.0
        projected = staleness_now + commit_rate * remaining_s
        if projected > self.async_cfg.max_staleness:
            return "discard"
        return "resume" if remaining_frac <= 0.5 else "restart"

    def _handle_fault_arrival(self, upd: PendingUpdate, t: float, params):
        """A fault just struck ``upd``'s client at sim-time ``t``.

        Returns True when a recovery attempt was scheduled (the slot stays
        busy); False when the attempt's work is lost and the slot frees."""
        client = self.fleet[upd.client_idx]
        # the faulted attempt's backing job produces nothing further
        self.backend.release(upd.job_id, t)
        upd.job_id = ""
        policy = self.faults.recovery_policy
        if (policy == "adaptive" and upd.fault in RECOVERABLE_FAULTS
                and upd.retries < self.faults.max_retries):
            policy = self._choose_recovery(upd, t)
            self._recovery_actions.append(f"{upd.fault}:{policy}")
        if (upd.fault not in RECOVERABLE_FAULTS or policy == "discard"
                or upd.retries >= self.faults.max_retries):
            return False
        L = max(self.fl.local_steps, 1)
        start = t + self.faults.recovery_overhead_s
        if policy == "restart":
            # retry from scratch against the CURRENT global params: fresh
            # downlink, fresh batches, staleness resets to the live version
            upd.steps_done = 0
            down_bytes, up_bytes = self._payload_bytes_cache(params)
            ex = self.backend.execute(client, self.flops_per_client_round,
                                      up_bytes, start)
            # duration_s is the recovery baseline: the fault-free duration of
            # the attempt that will actually land.  The retry redraws its
            # contention noise (and re-queues under the scheduler backend),
            # so rebase — otherwise a lucky short retry yields a NEGATIVE
            # recovery time against the first attempt's draw
            upd.duration_s = ex.fault_free_s
            upd.work_s, upd.queue_wait_s = ex.work_s, ex.queue_wait_s
            self._train_client(upd, client, params)
            upd.dispatch_version = self.version
            self.comm.log(self.version, client.cid, "down", down_bytes,
                          link_for_site(ex.site or client.site))
        else:  # resume: re-run only the steps after the local checkpoint
            base = upd.work_s or upd.duration_s
            ex = self.backend.resume(client,
                                     base * (L - upd.steps_done) / L, start)
        upd.site, upd.job_id = (ex.site or upd.site), ex.job_id
        failed, fault, frac = self.fault_injector.draw_fault(
            client, include_preempt=not self.backend.handles_preemption)
        upd.retries += 1
        if failed and ex.full_run_s > 0:
            upd.failed, upd.fault = True, fault
            if policy == "resume":
                upd.steps_done += int(frac * (L - upd.steps_done))
            self._push_event(start + ex.queue_wait_s + frac * ex.full_run_s,
                             upd.seq, upd)
        elif ex.preempted:
            # the scheduler reclaimed the RETRY's spot instance too
            upd.failed, upd.fault = True, "preempt"
            if policy == "resume":
                upd.steps_done += int(ex.frac_done * (L - upd.steps_done))
            else:
                upd.steps_done = int(ex.frac_done * L)
            self._push_event(start + ex.duration_s, upd.seq, upd)
        else:
            upd.failed, upd.fault = False, ""
            self._push_event(start + ex.duration_s, upd.seq, upd)
        return True

    # --------------------------------------------------------------- commit
    def _stack_buffer(self):
        """Pad the live buffer to K and stack it for the jit'd commit step.

        ``ids`` carries per-commit SLOT indices that key the pairwise
        secure-agg masks.  Slot indices — not client cids — because mask
        cancellation requires unique participant ids within a commit, and
        a fast client can land two buffered updates in the same commit
        (each occupies its own slot/identity, like two logical
        participants).  Padding slots carry mask 0, so every pair mask
        touching them is unwound (seed-reveal stand-in)."""
        K = self.async_cfg.buffer_size
        ups = [u for u, _ in self._buffer]
        zero = jax.tree.map(jnp.zeros_like, ups[0].delta)
        deltas = [u.delta for u in ups] + [zero] * (K - len(ups))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        pad = K - len(ups)
        weights = jnp.asarray([u.weight for u in ups] + [0.0] * pad,
                              jnp.float32)
        stal = [self.version - u.dispatch_version for u in ups]
        staleness = jnp.asarray(stal + [0] * pad, jnp.float32)
        losses = jnp.asarray([u.loss for u in ups] + [0.0] * pad, jnp.float32)
        mask = jnp.asarray([1.0] * len(ups) + [0.0] * pad, jnp.float32)
        ids = jnp.arange(K, dtype=jnp.int32)
        return stacked, weights, staleness, losses, mask, ids, stal, ups

    def _materialize(self):
        """Deferred-training hook: engines that defer the jit'd client
        update at dispatch time (BatchedAsyncOrchestrator) compute every
        pending delta here, in batched chunks.  Called before any code that
        reads ``upd.delta``/``upd.loss`` — the commit below and the
        checkpoint serializer.  No-op in the per-event engine (deltas are
        computed eagerly at dispatch)."""

    def _materialize_for_commit(self):
        """Materialize ONLY what the imminent commit reads.  The baseline
        delegates to the full hook; the event-window engine narrows it to
        the buffered updates so off-buffer jobs stay queued on-device."""
        self._materialize()

    def _commit_host_fetch(self, metrics, ups):
        """The ONE host-sync point of a commit: fetch the commit's
        delta_norm plus the per-update losses the CommitLog needs.
        Returns (delta_norm: float, losses: list[float]).  The baseline
        losses are already host floats; the event-window engine overrides
        this to bundle its deferred loss buckets into the same fetch."""
        return (float(self._host_fetch(metrics["delta_norm"])),
                [float(u.loss) for u in ups])

    def engine_state(self) -> dict:
        """Engine-private checkpoint payload (beyond the shared serializer's
        fields).  The per-event engine has none."""
        return {}

    def _after_restore(self):
        """Called by the checkpoint loader after all shared state is in
        place, so engines can rebuild derived structures (cohort counters,
        deferred-job caches).  The baseline rebuilds the buffered-arrival
        mirror the timeout flush reads."""
        self._buffer_t = np.asarray([a for _, a in self._buffer], np.float64)

    def _commit_chunked(self, params, server_state, ups, stal, alpha, r):
        """Accumulate the buffer C slots at a time: one device call per
        chunk plus one finalize, instead of stacking all K slots into a
        single [K, ...] tree.  Chunk k derives its rng by fold_in(r, k) and
        uses arange(C) slot ids, so secure-agg masks cancel chunk-locally."""
        C = self.async_cfg.commit_chunk
        acc_step, fin_step = self._chunk_steps
        acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        wsum = jnp.float32(0.0)
        ids = jnp.arange(C, dtype=jnp.int32)
        for k, lo in enumerate(range(0, len(ups), C)):
            chunk = ups[lo:lo + C]
            pad = C - len(chunk)
            zero = jax.tree.map(jnp.zeros_like, chunk[0].delta)
            deltas = [u.delta for u in chunk] + [zero] * pad
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
            weights = jnp.asarray([u.weight for u in chunk] + [0.0] * pad,
                                  jnp.float32)
            staleness = jnp.asarray(stal[lo:lo + C] + [0] * pad, jnp.float32)
            losses = jnp.asarray([u.loss for u in chunk] + [0.0] * pad,
                                 jnp.float32)
            mask = jnp.asarray([1.0] * len(chunk) + [0.0] * pad, jnp.float32)
            acc, wsum = acc_step(acc, wsum, stacked, weights, staleness,
                                 losses, mask, ids, jnp.float32(alpha),
                                 jax.random.fold_in(r, k))
        return fin_step(params, server_state, acc, wsum)

    def _do_commit(self, params, server_state, at_time: float,
                   timeout: bool = False):
        t0 = perf_counter()
        snap = dict(self._phase)
        self._materialize_for_commit()
        ups = [u for u, _ in self._buffer]
        stal = [self.version - u.dispatch_version for u in ups]
        r = self._next_key()
        alpha = self._alpha
        if self._chunk_steps is not None:
            params, server_state, metrics = self._commit_chunked(
                params, server_state, ups, stal, alpha, r)
        else:
            stacked, weights, staleness, losses, mask, ids, _, _ = \
                self._stack_buffer()
            params, server_state, metrics = self._commit_step(
                params, server_state, stacked, weights, staleness, losses,
                mask, ids, jnp.float32(alpha), r)
        self.version += 1
        self.fault_injector.step_round()
        self.updates_applied += len(ups)
        delta_norm, up_losses = self._commit_host_fetch(metrics, ups)
        if self._staleness_ctrl is not None:
            # feed the controller AFTER the commit: alpha moves for the next
            # one, deterministically from observed staleness + norm drift
            self._alpha = self._staleness_ctrl.update(stal, delta_norm)
        down_b, up_b = self._payload_bytes_cache(params)
        losses = [l for l in up_losses if np.isfinite(l)]
        rec = [u.recovery_s for u in ups if u.retries]
        log = CommitLog(
            commit=self.version, sim_time=at_time, n_updates=len(ups),
            mean_staleness=float(np.mean(stal)) if stal else 0.0,
            max_staleness=int(max(stal)) if stal else 0,
            client_loss=float(np.mean(losses)) if losses else float("nan"),
            delta_norm=delta_norm,
            bytes_up=self._buffer_bytes, timeout_commit=timeout,
            n_recovered=len(rec),
            recovery_time_s=float(np.mean(rec)) if rec else 0.0,
            staleness_alpha=alpha,
            mask_overhead_bytes=(up_b - down_b) * len(ups)
            if self.fl.secure_agg else 0,
            queue_wait_s=(float(np.mean([u.queue_wait_s for u in ups]))
                          if ups else 0.0),
            n_overflow=sum(1 for u in ups
                           if u.site and u.site
                           != self.fleet[u.client_idx].site),
            recovery_actions=self._recovery_actions)
        self._recovery_actions = []
        if self.eval_fn and (self.version % self.eval_every == 0):
            log.eval_metric = float(self._host_fetch(self.eval_fn(params)))
        self.logs.append(log)
        self._buffer = []
        self._buffer_bytes = 0
        self._buffer_t = np.empty(0)
        # everything since the previous commit not booked to an inner phase
        # is commit work; flush the window's phase accounting into the log
        inner = sum(self._phase[k] - snap[k] for k in snap)
        self._phase["commit"] += perf_counter() - t0 - inner
        log.phase_wall = {k: round(v, 6) for k, v in self._phase.items()}
        log.phase_wall["host_syncs"] = self._host_syncs
        self._phase = {k: 0.0 for k in self._phase}
        self._host_syncs = 0
        return params, server_state

    def _flush_timeouts(self, params, server_state, now: float):
        """Commit a partial buffer whose oldest update has waited >= T.

        The deadline is (oldest buffered arrival + T), not (last commit + T):
        the latter could stamp a commit at a sim-time BEFORE the buffer's
        first update even arrived when arrivals are sparse.  Every buffered
        update arrived no later than the previous event pop, so all of them
        predate the deadline."""
        T = self.async_cfg.commit_timeout_s
        # O(1) hot-path guard: the head of the array-backed arrival mirror
        # is the oldest buffered arrival (the buffer is append-ordered by
        # event time), so one comparison rules the common case out
        if (not T or self._buffer_t.size == 0
                or self._buffer_t[0] + T > now):
            return params, server_state
        while self._buffer_t.size and self._buffer_t[0] + T <= now:
            params, server_state = self._do_commit(
                params, server_state, float(self._buffer_t[0] + T),
                timeout=True)
        return params, server_state

    # ------------------------------------------------------------------ run
    def save_checkpoint(self, params, server_state):
        """Snapshot the FULL orchestrator state through the checkpoint
        manager; a fresh orchestrator restored from it replays the exact
        trajectory an uninterrupted run would have taken."""
        if self.checkpoint_mgr is None:
            raise ValueError("no checkpoint_mgr configured")
        self.checkpoint_mgr.save_async(self, params, server_state)

    def run(self, params, num_commits: int, server_state=None,
            max_sim_time: float = 0.0, verbose: bool = False):
        """Run until `num_commits` server commits (or `max_sim_time`)."""
        if server_state is None:
            server_state = self.init_server_state(params)
        self._top_up(params)

        last_ckpt = self.version
        while self._events and self.version < num_commits:
            t, seq, upd = self._pop_event()
            if max_sim_time and t > max_sim_time:
                # budget exhausted before this arrival: flush any timeout
                # deadlines that fall inside the budget, put the event back
                # so a continuation run can still process it, and pin the
                # clock to the budget actually simulated
                params, server_state = self._flush_timeouts(
                    params, server_state, max_sim_time)
                self._push_event(t, seq, upd)
                self.clock = max_sim_time
                break
            params, server_state = self._flush_timeouts(params, server_state, t)
            if self.version >= num_commits:
                self._push_event(t, seq, upd)
                break
            self.clock = max(self.clock, t)
            client = self.fleet[upd.client_idx]
            self.events_processed.append(
                (round(t, 9), upd.seq, upd.cid, bool(upd.failed), upd.fault))
            if upd.failed:
                if self._handle_fault_arrival(upd, t, params):
                    continue            # slot stays busy with the retry
                self.lost_to_faults += 1
                self._abandon_update(upd)
                self._inflight.discard(upd.cid)
                # history in dispatch-counter units, matching select()'s view
                client.record(False, t - upd.dispatch_time, self._seq)
            else:
                self._inflight.discard(upd.cid)
                elapsed = t - upd.dispatch_time
                client.record(True, elapsed, self._seq)
                if upd.retries:
                    upd.recovery_s = elapsed - upd.duration_s
                    self.recovered_updates += 1
                    self.recovery_time_total += upd.recovery_s
                # the client transmitted regardless of what the server does
                # with the update — dropped-as-stale still paid the uplink
                # (the MASKED wire size under secure_agg), over the link of
                # the site the attempt was PLACED on (overflowed HPC jobs
                # upload from the cloud)
                up_bytes = self._payload_bytes_cache(params)[1]
                self.comm.log(self.version, upd.cid, "up", up_bytes,
                              link_for_site(upd.site or client.site))
                staleness = self.version - upd.dispatch_version
                if staleness > self.async_cfg.max_staleness:
                    self.dropped_stale += 1
                    self._abandon_update(upd)
                else:
                    self._buffer.append((upd, t))
                    self._buffer_bytes += up_bytes
                    self._buffer_t = np.append(self._buffer_t, t)
            if len(self._buffer) >= self.async_cfg.buffer_size:
                params, server_state = self._do_commit(params, server_state, t)
                if verbose and self.logs:
                    lg = self.logs[-1]
                    print(f"commit {lg.commit:4d} t={lg.sim_time:8.1f}s "
                          f"loss={lg.client_loss:.4f} "
                          f"stale={lg.mean_staleness:.1f} "
                          f"eval={lg.eval_metric:.4f}")
            self._dispatch_one(params, self.clock)
            # checkpoint only here, at the loop-top-equivalent safe point:
            # the popped event is fully processed and its freed slot
            # re-dispatched, so restore + continue == never stopped
            if (self.checkpoint_mgr and self.checkpoint_every
                    and self.version != last_ckpt
                    and self.version % self.checkpoint_every == 0):
                self.save_checkpoint(params, server_state)
                last_ckpt = self.version
        if self.checkpoint_mgr is not None:
            # terminal snapshot (kill-by-budget / commit target reached) —
            # taken BEFORE the eval backfill below, which is presentation
            # only and must not leak into the resumed trajectory
            self.save_checkpoint(params, server_state)
        # sync run() forces an eval on the final round; mirror that so the
        # terminal commit always carries a real metric
        if self.eval_fn and self.logs and not np.isfinite(
                self.logs[-1].eval_metric):
            self.logs[-1].eval_metric = float(self.eval_fn(params))
        return params, server_state

    # ------------------------------------------------------------- metrics
    @property
    def commits_per_sim_second(self) -> float:
        return self.version / self.clock if self.clock else 0.0

    @property
    def updates_per_sim_second(self) -> float:
        return self.updates_applied / self.clock if self.clock else 0.0
