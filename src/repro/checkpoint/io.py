"""Checkpointing: atomic pytree save/restore + federated round state.

Fault tolerance at the *orchestrator* level (paper §3.1): if the central
orchestrator dies, training resumes from (global model, server opt state,
round counter, client histories)."""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path

from repro.comm.payload import deserialize_tree, serialize_tree


def _atomic_write(path: Path, data: bytes):
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def save_pytree(path, tree):
    _atomic_write(Path(path), serialize_tree(tree))


def load_pytree(path, like):
    with open(path, "rb") as f:
        return deserialize_tree(f.read(), like=like)


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self.dir.mkdir(parents=True, exist_ok=True)

    def step_dir(self, rnd: int) -> Path:
        return self.dir / f"round_{rnd:06d}"

    def _finalize(self, step_dir: Path):
        _atomic_write(self.dir / "LATEST", step_dir.name.encode())
        self._gc()

    def save(self, rnd: int, params, server_state=None, meta: dict | None = None):
        step_dir = self.step_dir(rnd)
        save_pytree(step_dir / "params.bin", params)
        # save whenever a server state was handed in, even a leaf-less pytree
        # like fedavg's () — "empty state" and "no state" must restore
        # differently (meta/round still matter for resume either way)
        if server_state is not None:
            save_pytree(step_dir / "server_state.bin", server_state)
        _atomic_write(step_dir / "meta.json",
                      json.dumps({"round": rnd, **(meta or {})}).encode())
        self._finalize(step_dir)

    def _gc(self):
        steps = sorted(d for d in self.dir.iterdir()
                       if d.is_dir() and d.name.startswith("round_"))
        for d in steps[:-self.keep]:
            for f in d.iterdir():
                f.unlink()
            d.rmdir()

    def latest_round(self) -> int | None:
        latest = self.dir / "LATEST"
        if not latest.exists():
            return None
        name = latest.read_bytes().decode().strip()
        return int(name.split("_")[1])

    def restore(self, params_like, server_state_like=None, rnd: int | None = None):
        rnd = rnd if rnd is not None else self.latest_round()
        if rnd is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        step_dir = self.step_dir(rnd)
        params = load_pytree(step_dir / "params.bin", params_like)
        server_state = None
        ss_path = step_dir / "server_state.bin"
        if server_state_like is not None and ss_path.exists():
            server_state = load_pytree(ss_path, server_state_like)
        meta = json.loads((step_dir / "meta.json").read_text())
        return params, server_state, meta
