"""Crash-safe checkpoint/resume for the event-driven async regime.

The synchronous Orchestrator is stateless-restartable from
(params, server_state, round counter) alone; ``AsyncOrchestrator`` is not —
between two commits it carries a pending-update buffer, an event heap of
in-flight clients (each holding a trained delta against an old params
snapshot), four independent RNG streams (dispatch/simulation, jax client
keys, selection, fault injection), per-client data-sampler generators,
fleet performance histories, the commit log and comm ledger — and, under
``--exec-backend scheduler``, the simulated SLURM/K8s pool itself (queued
and in-flight jobs, autoscale level, adapter RNG streams).  Dropping any
of it on restore forks the trajectory.

``AsyncCheckpointManager`` serialises ALL of it:

  round_%06d/
    params.bin            global params            (serialize_tree)
    server_state.bin      server optimizer state   (serialize_tree)
    delta_%06d.bin        one file per pending update carrying a delta,
                          keyed by its dispatch seq (in-flight or buffered)
    async_state.json      every host-side scalar/RNG/heap/log field
    meta.json             {round: commit counter, mode: "async", clock}

Each snapshot is self-contained — it carries the full commit log, comm
ledger and processed-event trace, which is what lets a restored run's
history compare equal to a never-killed one.  The cost is snapshots that
grow linearly with run length; for very long runs, checkpoint sparsely
(``checkpoint_every``) rather than every commit.

Restore targets a FRESHLY CONSTRUCTED orchestrator built with the same
configuration (fleet layout, FLConfig/AsyncConfig, dataset seed); every
stochastic stream is overwritten with the saved state, so

    run(N)  ==  run-to-k -> kill -> restore -> run(N)

bit-for-bit — the invariant ``tests/test_async_resume.py`` pins.
"""
from __future__ import annotations

import heapq
import json
from dataclasses import asdict

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (CheckpointManager, _atomic_write, load_pytree,
                                 save_pytree)

_UPD_FIELDS = ("seq", "cid", "client_idx", "dispatch_version",
               "dispatch_time", "duration_s", "loss", "weight", "failed",
               "fault", "steps_done", "retries", "recovery_s",
               "work_s", "queue_wait_s", "site", "job_id")


def _upd_meta(upd) -> dict:
    d = {f: getattr(upd, f) for f in _UPD_FIELDS}
    d["has_delta"] = upd.delta is not None
    return d


def async_state_dict(orch) -> tuple[dict, dict]:
    """(json-serialisable host state, {seq: delta pytree} for pending work)."""
    # deferred-training engines hold pending updates as un-run jobs bound to
    # live params refs; force them into concrete deltas so the snapshot is
    # self-contained and readable by ANY engine (cross-engine restore)
    orch._materialize()
    deltas = {}
    events = []
    for t, seq, upd in orch._events:
        events.append({"time": t, **_upd_meta(upd)})
        if upd.delta is not None:
            deltas[upd.seq] = upd.delta
    buffer = []
    for upd, arrival in orch._buffer:
        buffer.append({"arrival": arrival, **_upd_meta(upd)})
        if upd.delta is not None:
            deltas[upd.seq] = upd.delta
    state = {
        "config": {"buffer_size": orch.async_cfg.buffer_size,
                   "local_steps": orch.fl.local_steps,
                   "n_fleet": len(orch.fleet),
                   "secure_agg": orch.fl.secure_agg,
                   "staleness_exponent":
                       str(orch.async_cfg.staleness_exponent),
                   "commit_chunk": orch.async_cfg.commit_chunk,
                   "exec_backend": orch.backend.name},
        # scheduler state: node pools, queued/in-flight jobs, adapter RNG —
        # empty for the closed-form backend (its randomness is orch.rng)
        "backend": orch.backend.state(),
        "recovery_actions": list(orch._recovery_actions),
        "clock": orch.clock,
        # staleness-discount state: the alpha the NEXT commit will use, plus
        # the adaptive controller's EMAs (None when the exponent is constant)
        "alpha": orch._alpha,
        "staleness_ctrl": (orch._staleness_ctrl.state()
                           if orch._staleness_ctrl is not None else None),
        "version": orch.version,
        "updates_applied": orch.updates_applied,
        "dropped_stale": orch.dropped_stale,
        "recovered_updates": orch.recovered_updates,
        "lost_to_faults": orch.lost_to_faults,
        "recovery_time_total": orch.recovery_time_total,
        "seq": orch._seq,
        "rng": orch.rng.bit_generator.state,
        "jrng": np.asarray(orch.jrng, np.uint32).tolist(),
        "selection_rng": orch.selection.rng.bit_generator.state,
        "fault": orch.fault_injector.state(),
        "inflight": sorted(orch._inflight),
        "buffer_bytes": orch._buffer_bytes,
        "events": events,
        "buffer": buffer,
        "logs": [asdict(l) for l in orch.logs],
        "comm": [asdict(r) for r in orch.comm.records],
        "fleet": _fleet_histories(orch.fleet),
        "events_processed": [list(e) for e in orch.events_processed],
    }
    # per-client data-sampler generators: lazy datasets serialise only the
    # touched ones (O(participants), not O(population))
    if hasattr(orch.fed_data, "rng_states"):
        state["data_rngs_lazy"] = orch.fed_data.rng_states()
    else:
        state["data_rngs"] = [g.bit_generator.state
                              for g in orch.fed_data._rngs]
    eng = orch.engine_state()
    if eng:
        state["engine"] = eng
    return state, deltas


def _fleet_histories(fleet) -> list[dict]:
    # lazy fleets (CohortFleet) serialise only the clients that ever
    # dispatched — the rest are reconstructable from the cohort specs
    return [{"cid": c.cid, "completions": c.completions,
             "failures": c.failures, "ema_round_time": c.ema_round_time,
             "last_selected_round": c.last_selected_round}
            for c in (fleet.live.values() if hasattr(fleet, "live")
                      else fleet)]


def _restore_fleet_histories(fleet, histories: list[dict]):
    """Snapshots carry histories only for touched clients; a fresh fleet's
    untouched clients already hold the default history.  Lazy fleets index
    by cid directly (their cid == index invariant materializes the client);
    list fleets go through a cid map so sub-fleets with relabelled cids
    restore correctly too."""
    if hasattr(fleet, "live"):
        lookup = lambda cid: fleet[cid]               # noqa: E731
    else:
        by_cid = {c.cid: c for c in fleet}
        lookup = lambda cid: by_cid[cid]              # noqa: E731
    for h in histories:
        c = lookup(int(h["cid"]))
        c.completions = int(h["completions"])
        c.failures = int(h["failures"])
        c.ema_round_time = float(h["ema_round_time"])
        c.last_selected_round = int(h["last_selected_round"])


def load_async_state(orch, state: dict, deltas: dict):
    """Overwrite a freshly constructed orchestrator's mutable state."""
    from repro.comm.transport import TransferRecord
    from repro.orchestrator.async_server import CommitLog, PendingUpdate

    cfg = state["config"]
    # .get() defaults keep pre-secure-agg-era checkpoints restorable by a
    # matching (plain, constant-exponent) orchestrator
    if cfg["buffer_size"] != orch.async_cfg.buffer_size \
            or cfg["local_steps"] != orch.fl.local_steps \
            or cfg["n_fleet"] != len(orch.fleet) \
            or cfg.get("secure_agg", False) != orch.fl.secure_agg \
            or cfg.get("exec_backend", "closed-form") != orch.backend.name \
            or cfg.get("commit_chunk", 0) != orch.async_cfg.commit_chunk \
            or cfg.get("staleness_exponent",
                       str(orch.async_cfg.staleness_exponent)) \
            != str(orch.async_cfg.staleness_exponent):
        raise ValueError(
            f"checkpoint was written by an orchestrator with config {cfg}; "
            f"restore requires an identically configured one")
    if state.get("backend"):
        orch.backend.set_state(state["backend"])
    orch._recovery_actions = list(state.get("recovery_actions", []))
    orch.clock = float(state["clock"])
    orch._alpha = float(state.get("alpha", orch.async_cfg.initial_exponent()))
    if orch._staleness_ctrl is not None and state.get("staleness_ctrl"):
        orch._staleness_ctrl.set_state(state["staleness_ctrl"])
    orch.version = int(state["version"])
    orch.updates_applied = int(state["updates_applied"])
    orch.dropped_stale = int(state["dropped_stale"])
    orch.recovered_updates = int(state["recovered_updates"])
    orch.lost_to_faults = int(state["lost_to_faults"])
    orch.recovery_time_total = float(state["recovery_time_total"])
    orch._seq = int(state["seq"])
    orch.rng.bit_generator.state = state["rng"]
    orch.jrng = jnp.asarray(state["jrng"], jnp.uint32)
    orch.selection.rng.bit_generator.state = state["selection_rng"]
    orch.fault_injector.set_state(state["fault"])
    if "data_rngs_lazy" in state:
        if not hasattr(orch.fed_data, "load_rng_states"):
            raise ValueError(
                "checkpoint carries lazy per-client rng state but the "
                "restore dataset is not a VirtualFederatedDataset")
        orch.fed_data.load_rng_states(state["data_rngs_lazy"])
    else:
        for g, s in zip(orch.fed_data._rngs, state["data_rngs"]):
            g.bit_generator.state = s

    def mk_upd(meta):
        # missing keys (pre-backend-era checkpoints) fall to field defaults
        upd = PendingUpdate(**{f: meta[f] for f in _UPD_FIELDS if f in meta})
        if meta["has_delta"]:
            upd.delta = deltas[upd.seq]
        return upd

    orch._events = [(e["time"], e["seq"], mk_upd(e)) for e in state["events"]]
    heapq.heapify(orch._events)
    orch._buffer = [(mk_upd(b), b["arrival"]) for b in state["buffer"]]
    orch._inflight = set(state["inflight"])
    orch._buffer_bytes = int(state["buffer_bytes"])
    orch.logs = [CommitLog(**l) for l in state["logs"]]
    orch.comm.records = [TransferRecord(**r) for r in state["comm"]]
    orch.events_processed = [tuple(e) for e in state["events_processed"]]
    _restore_fleet_histories(orch.fleet, state["fleet"])
    if state.get("engine"):
        if not hasattr(orch, "load_engine_state"):
            raise ValueError(
                "checkpoint carries engine-private state (cohort draw "
                "blocks) but the restore orchestrator is not a "
                "BatchedAsyncOrchestrator")
        orch.load_engine_state(state["engine"])
    orch._after_restore()


# ------------------------------------------------------------------ sync
def sync_state_dict(orch) -> dict:
    """Full mutable state of a synchronous ``Orchestrator``.

    The flat sync path restarts statelessly from (params, round counter),
    accepting a forked RNG trajectory; hierarchical facilities cannot —
    a tier-1 facility's RNG streams, clock, logs and fleet histories feed
    later tier-2 epochs, so bit-identical resume needs all of it."""
    return {
        "config": {"mode": "sync", "n_fleet": len(orch.fleet),
                   "num_clients": orch.fl.num_clients,
                   "local_steps": orch.fl.local_steps,
                   "secure_agg": orch.fl.secure_agg,
                   "exec_backend": orch.backend.name},
        "backend": orch.backend.state(),
        "clock": orch.virtual_clock,
        "rng": orch.rng.bit_generator.state,
        "jrng": np.asarray(orch.jrng, np.uint32).tolist(),
        "selection_rng": orch.selection.rng.bit_generator.state,
        "fault": orch.fault_injector.state(),
        # selection returns numpy ints — coerce for the json encoder
        "logs": [{**asdict(l), "selected": [int(s) for s in l.selected]}
                 for l in orch.logs],
        "comm": [asdict(r) for r in orch.comm.records],
        "fleet": _fleet_histories(orch.fleet),
        "data_rngs": [g.bit_generator.state for g in orch.fed_data._rngs],
    }


def load_sync_state(orch, state: dict):
    """Overwrite a freshly constructed sync ``Orchestrator``'s state."""
    from repro.comm.transport import TransferRecord
    from repro.orchestrator.server import RoundLog

    cfg = state["config"]
    if cfg["n_fleet"] != len(orch.fleet) \
            or cfg["num_clients"] != orch.fl.num_clients \
            or cfg["local_steps"] != orch.fl.local_steps \
            or cfg["secure_agg"] != orch.fl.secure_agg \
            or cfg["exec_backend"] != orch.backend.name:
        raise ValueError(
            f"checkpoint was written by an orchestrator with config {cfg}; "
            f"restore requires an identically configured one")
    if state.get("backend"):
        orch.backend.set_state(state["backend"])
    orch.virtual_clock = float(state["clock"])
    orch.rng.bit_generator.state = state["rng"]
    orch.jrng = jnp.asarray(state["jrng"], jnp.uint32)
    orch.selection.rng.bit_generator.state = state["selection_rng"]
    orch.fault_injector.set_state(state["fault"])
    orch.logs = [RoundLog(**l) for l in state["logs"]]
    orch.comm.records = [TransferRecord(**r) for r in state["comm"]]
    _restore_fleet_histories(orch.fleet, state["fleet"])
    for g, s in zip(orch.fed_data._rngs, state["data_rngs"]):
        g.bit_generator.state = s


# ------------------------------------------------------------- hierarchy
_FAC_UPD_FIELDS = ("seq", "fac", "dispatch_version", "dispatch_time",
                   "wall_s", "up_seconds", "weight", "loss")


def _fac_upd_meta(upd) -> dict:
    d = {f: getattr(upd, f) for f in _FAC_UPD_FIELDS}
    d["has_delta"] = upd.delta is not None
    return d


def hier_state_dict(hier):
    """(json state, {seq: tier-2 delta}, [per-facility {seq: delta}]).

    Tier-2 state mirrors the async serializer (heap, buffer, RNGs, logs,
    WAN comm ledger); each facility contributes its own sub-orchestrator
    snapshot via the regime-matching serializer above."""
    t2_deltas = {}
    events = []
    for t, seq, upd in hier._events:
        events.append({"time": t, **_fac_upd_meta(upd)})
        if upd.delta is not None:
            t2_deltas[upd.seq] = upd.delta
    buffer = []
    for upd, arrival in hier._buffer:
        buffer.append({"arrival": arrival, **_fac_upd_meta(upd)})
        if upd.delta is not None:
            t2_deltas[upd.seq] = upd.delta
    fac_states, fac_deltas = [], []
    for fac in hier.facilities:
        if fac.mode == "async":
            st, fd = async_state_dict(fac.orch)
        else:
            st, fd = sync_state_dict(fac.orch), {}
        fac_states.append({"mode": fac.mode, "name": fac.name,
                           "local_rounds": fac.local_rounds, "state": st})
        fac_deltas.append(fd)
    state = {
        "config": {"n_facilities": len(hier.facilities),
                   "inter_mode": hier.inter_mode,
                   "buffer_size": hier.async_cfg.buffer_size,
                   "secure_agg": hier.fl.secure_agg,
                   "modes": [f.mode for f in hier.facilities],
                   "local_rounds": [f.local_rounds for f in hier.facilities]},
        "clock": hier.clock,
        "version": hier.version,
        "seq": hier._seq,
        "alpha": hier._alpha,
        "dropped_stale": hier.dropped_stale,
        "buffer_bytes": hier._buffer_bytes,
        "rng": hier.rng.bit_generator.state,
        "jrng": np.asarray(hier.jrng, np.uint32).tolist(),
        "events": events,
        "buffer": buffer,
        "logs": [asdict(l) for l in hier.logs],
        "comm": [asdict(r) for r in hier.comm.records],
        "facilities": fac_states,
    }
    return state, t2_deltas, fac_deltas


def load_hier_state(hier, state: dict, t2_deltas: dict,
                    fac_deltas: list[dict]):
    """Overwrite a freshly constructed ``HierarchicalOrchestrator``."""
    from repro.comm.transport import TransferRecord
    from repro.orchestrator.async_server import CommitLog
    from repro.orchestrator.hierarchy import FacilityUpdate

    cfg = state["config"]
    if cfg["n_facilities"] != len(hier.facilities) \
            or cfg["inter_mode"] != hier.inter_mode \
            or cfg["buffer_size"] != hier.async_cfg.buffer_size \
            or cfg["secure_agg"] != hier.fl.secure_agg \
            or cfg["modes"] != [f.mode for f in hier.facilities] \
            or cfg["local_rounds"] != [f.local_rounds
                                       for f in hier.facilities]:
        raise ValueError(
            f"checkpoint was written by a hierarchy with config {cfg}; "
            f"restore requires an identically configured one")
    hier.clock = float(state["clock"])
    hier.version = int(state["version"])
    hier._seq = int(state["seq"])
    hier._alpha = float(state["alpha"])
    hier.dropped_stale = int(state["dropped_stale"])
    hier._buffer_bytes = int(state["buffer_bytes"])
    hier.rng.bit_generator.state = state["rng"]
    hier.jrng = jnp.asarray(state["jrng"], jnp.uint32)

    def mk_upd(meta):
        upd = FacilityUpdate(**{f: meta[f] for f in _FAC_UPD_FIELDS})
        if meta["has_delta"]:
            upd.delta = t2_deltas[upd.seq]
        return upd

    hier._events = [(e["time"], e["seq"], mk_upd(e))
                    for e in state["events"]]
    heapq.heapify(hier._events)
    hier._buffer = [(mk_upd(b), b["arrival"]) for b in state["buffer"]]
    hier.logs = [CommitLog(**l) for l in state["logs"]]
    hier.comm.records = [TransferRecord(**r) for r in state["comm"]]
    for fac, meta, fd in zip(hier.facilities, state["facilities"],
                             fac_deltas):
        if meta["mode"] != fac.mode:
            raise ValueError(
                f"facility {meta['name']} was checkpointed in "
                f"{meta['mode']} mode; restore facility runs {fac.mode}")
        if fac.mode == "async":
            load_async_state(fac.orch, meta["state"], fd)
        else:
            load_sync_state(fac.orch, meta["state"])


class AsyncCheckpointManager(CheckpointManager):
    """CheckpointManager grown to cover the async orchestrator's full state.

    ``save``/``restore`` (params + server state + meta) keep working for the
    sync path; ``save_async``/``restore_async`` additionally round-trip the
    event heap, pending-update buffer and every RNG stream."""

    def save_async(self, orch, params, server_state):
        step_dir = self.step_dir(orch.version)
        save_pytree(step_dir / "params.bin", params)
        if server_state is not None:
            save_pytree(step_dir / "server_state.bin", server_state)
        state, deltas = async_state_dict(orch)
        for seq, delta in deltas.items():
            save_pytree(step_dir / f"delta_{seq:06d}.bin", delta)
        _atomic_write(step_dir / "async_state.json",
                      json.dumps(state).encode())
        _atomic_write(step_dir / "meta.json",
                      json.dumps({"round": orch.version, "mode": "async",
                                  "clock": orch.clock}).encode())
        self._finalize(step_dir)

    def restore_async(self, orch, params_like, rnd: int | None = None):
        """Load the latest (or ``rnd``-th) snapshot INTO ``orch``.

        ``orch`` must be freshly constructed with the same configuration as
        the writer.  Returns ``(params, server_state)`` ready for
        ``orch.run(params, N, server_state=server_state)``."""
        rnd = rnd if rnd is not None else self.latest_round()
        if rnd is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        step_dir = self.step_dir(rnd)
        params = load_pytree(step_dir / "params.bin", params_like)
        server_state = orch.init_server_state(params)
        ss_path = step_dir / "server_state.bin"
        if ss_path.exists():
            server_state = load_pytree(ss_path, server_state)
        state = json.loads((step_dir / "async_state.json").read_text())
        seqs = [e["seq"] for e in state["events"] + state["buffer"]
                if e["has_delta"]]
        deltas = {seq: load_pytree(step_dir / f"delta_{seq:06d}.bin",
                                   params_like)
                  for seq in seqs}
        load_async_state(orch, state, deltas)
        return params, server_state

    # ------------------------------------------------------- hierarchy
    def save_hier(self, hier, params, server_state):
        """Snapshot a two-tier run: tier-2 params/heap/buffer/RNGs plus
        every facility's full sub-orchestrator state, one self-contained
        directory per tier-2 commit."""
        step_dir = self.step_dir(hier.version)
        save_pytree(step_dir / "params.bin", params)
        if server_state is not None:
            save_pytree(step_dir / "server_state.bin", server_state)
        state, t2_deltas, fac_deltas = hier_state_dict(hier)
        for seq, delta in t2_deltas.items():
            save_pytree(step_dir / f"t2delta_{seq:06d}.bin", delta)
        for f, fd in enumerate(fac_deltas):
            for seq, delta in fd.items():
                save_pytree(step_dir / f"fac{f:02d}_delta_{seq:06d}.bin",
                            delta)
        _atomic_write(step_dir / "hier_state.json",
                      json.dumps(state).encode())
        _atomic_write(step_dir / "meta.json",
                      json.dumps({"round": hier.version, "mode": "hier",
                                  "clock": hier.clock}).encode())
        self._finalize(step_dir)

    def restore_hier(self, hier, params_like, rnd: int | None = None):
        """Load the latest (or ``rnd``-th) hierarchy snapshot INTO ``hier``
        (freshly constructed, same facility layout/configs as the writer)."""
        rnd = rnd if rnd is not None else self.latest_round()
        if rnd is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        step_dir = self.step_dir(rnd)
        params = load_pytree(step_dir / "params.bin", params_like)
        server_state = hier.init_server_state(params)
        ss_path = step_dir / "server_state.bin"
        if ss_path.exists():
            server_state = load_pytree(ss_path, server_state)
        state = json.loads((step_dir / "hier_state.json").read_text())
        t2_seqs = [e["seq"] for e in state["events"] + state["buffer"]
                   if e["has_delta"]]
        t2_deltas = {seq: load_pytree(step_dir / f"t2delta_{seq:06d}.bin",
                                      params_like)
                     for seq in t2_seqs}
        fac_deltas = []
        for f, meta in enumerate(state["facilities"]):
            fd = {}
            if meta["mode"] == "async":
                st = meta["state"]
                for e in st["events"] + st["buffer"]:
                    if e["has_delta"]:
                        fd[e["seq"]] = load_pytree(
                            step_dir / f"fac{f:02d}_delta_{e['seq']:06d}.bin",
                            params_like)
            fac_deltas.append(fd)
        load_hier_state(hier, state, t2_deltas, fac_deltas)
        return params, server_state
