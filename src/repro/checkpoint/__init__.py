from repro.checkpoint.io import CheckpointManager, load_pytree, save_pytree  # noqa: F401
from repro.checkpoint.async_state import AsyncCheckpointManager  # noqa: F401
