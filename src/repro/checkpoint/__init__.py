from repro.checkpoint.io import CheckpointManager, load_pytree, save_pytree  # noqa: F401
from repro.checkpoint.async_state import (  # noqa: F401
    AsyncCheckpointManager, async_state_dict, hier_state_dict,
    load_async_state, load_hier_state, load_sync_state, sync_state_dict,
)
