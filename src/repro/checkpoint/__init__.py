from repro.checkpoint.io import CheckpointManager, load_pytree, save_pytree  # noqa: F401
