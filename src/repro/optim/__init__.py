from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, momentum, adam, get_client_optimizer,
)
from repro.optim.server import (  # noqa: F401
    ServerOptimizer, fedavg_server, fedadam_server, fedyogi_server,
    get_server_optimizer,
)
