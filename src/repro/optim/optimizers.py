"""Client-side optimizers (pure-JAX, optax-free).

An Optimizer is a pair of pure functions:
    init(params)              -> opt_state
    update(grads, state, params, lr) -> (new_params, new_state)

FedAvg's local solver is plain SGD (McMahan et al. 2017); momentum and Adam
are provided for the server-side FedOpt family and for centralized baselines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer("sgd", init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype), state, grads)
        new_p = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
        return new_p, new_m

    return Optimizer("momentum", init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new_p = jax.tree.map(
            lambda p, m_, v_: p - (lr * (m_ / bc1) /
                                   (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new_p, {"m": m, "v": v, "t": t}

    return Optimizer("adam", init, update)


def get_client_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam}[name](**kw)
