"""Server-side optimizers applied to the aggregated federated delta.

FedAvg:  M_{r+1} = M_r + eta * Delta            (paper Algorithm 1, line 12)
FedAdam / FedYogi (Reddi et al. 2021): adaptive server updates — a
beyond-paper extension (DESIGN.md notes it; the paper only uses FedAvg-style
application of the aggregate).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ServerOptimizer:
    name: str
    init: Callable            # params -> state
    apply: Callable           # (params, delta, state) -> (params, state)


def fedavg_server(lr: float = 1.0) -> ServerOptimizer:
    def init(params):
        return ()

    def apply(params, delta, state):
        new = jax.tree.map(lambda p, d: p + lr * d.astype(p.dtype), params, delta)
        return new, state

    return ServerOptimizer("fedavg", init, apply)


def _adaptive(name: str, lr: float, b1: float, b2: float, tau: float):
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.full(p.shape, tau ** 2, jnp.float32), params),
        }

    def apply(params, delta, state):
        m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d.astype(jnp.float32),
                         state["m"], delta)
        if name == "fedadam":
            v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * jnp.square(
                d.astype(jnp.float32)), state["v"], delta)
        else:  # fedyogi
            def yogi(v_, d):
                d2 = jnp.square(d.astype(jnp.float32))
                return v_ - (1 - b2) * d2 * jnp.sign(v_ - d2)
            v = jax.tree.map(yogi, state["v"], delta)
        new_p = jax.tree.map(
            lambda p, m_, v_: p + (lr * m_ / (jnp.sqrt(v_) + tau)).astype(p.dtype),
            params, m, v)
        return new_p, {"m": m, "v": v}

    return ServerOptimizer(name, init, apply)


def fedadam_server(lr: float = 0.01, b1: float = 0.9, b2: float = 0.99,
                   tau: float = 1e-3) -> ServerOptimizer:
    return _adaptive("fedadam", lr, b1, b2, tau)


def fedyogi_server(lr: float = 0.01, b1: float = 0.9, b2: float = 0.99,
                   tau: float = 1e-3) -> ServerOptimizer:
    return _adaptive("fedyogi", lr, b1, b2, tau)


def get_server_optimizer(name: str, **kw) -> ServerOptimizer:
    return {"fedavg": fedavg_server, "fedadam": fedadam_server,
            "fedyogi": fedyogi_server}[name](**kw)
