"""SLURM adapter: renders real sbatch scripts; simulates a partition with a
fixed node pool and FIFO + backfill-ish start policy."""
from __future__ import annotations

import numpy as np

from repro.sched.adapter import JobHandle, JobSpec, JobState, SchedulerAdapter

SBATCH_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={name}
#SBATCH --nodes={nodes}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={cpus}
#SBATCH --mem={mem}G
{gpu_line}#SBATCH --time={time_min}
#SBATCH --output=logs/%x-%j.out

srun {command}
"""


class SlurmAdapter(SchedulerAdapter):
    prefix = "slurm-"

    def __init__(self, total_nodes: int = 30, speed_tflops: float = 16.0,
                 queue_noise: float = 0.0, seed: int = 0):
        super().__init__()
        self.total_nodes = total_nodes
        self.speed_tflops = speed_tflops
        self.queue_noise = queue_noise
        self.rng = np.random.default_rng(seed)
        self._work: dict[str, float] = {}     # job_id -> seconds of work

    def render_artifact(self, spec: JobSpec) -> str:
        gpu_line = (f"#SBATCH --gres=gpu:{spec.gpus_per_node}\n"
                    if spec.gpus_per_node else "")
        return SBATCH_TEMPLATE.format(
            name=spec.name, nodes=spec.nodes, cpus=spec.cpus_per_node,
            mem=spec.mem_gb, gpu_line=gpu_line,
            time_min=max(1, spec.time_limit_s // 60), command=spec.command)

    def set_workload(self, job_id: str, seconds: float):
        self._work[job_id] = seconds

    def _nodes_in_use(self) -> int:
        return sum(h.spec.nodes for h in self.running())

    def _try_start(self, handle: JobHandle) -> bool:
        return self._nodes_in_use() + handle.spec.nodes <= self.total_nodes

    def _runtime_s(self, spec: JobSpec) -> float:
        base = self._work.get(self._find_id(spec), 60.0)
        noise = self.rng.lognormal(0, self.queue_noise) if self.queue_noise else 1.0
        return min(base * noise, spec.time_limit_s)

    def _find_id(self, spec: JobSpec) -> str:
        for jid, h in self.jobs.items():
            if h.spec is spec:
                return jid
        return ""
