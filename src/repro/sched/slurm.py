"""SLURM adapter: renders real sbatch scripts; simulates a partition with a
fixed node pool and a strict-FIFO start policy.

Queue noise (shared-filesystem / co-tenant jitter) is a single lognormal
factor drawn per job at submit time — not re-drawn every clock tick — so a
job's runtime is fixed the moment it is submitted and replays identically
from a checkpoint."""
from __future__ import annotations

from repro.sched.adapter import JobHandle, JobSpec, SchedulerAdapter

SBATCH_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={name}
#SBATCH --nodes={nodes}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={cpus}
#SBATCH --mem={mem}G
{gpu_line}#SBATCH --time={time_min}
#SBATCH --output=logs/%x-%j.out

srun {command}
"""


class SlurmAdapter(SchedulerAdapter):
    prefix = "slurm-"

    def __init__(self, total_nodes: int = 30, speed_tflops: float = 16.0,
                 queue_noise: float = 0.0, seed: int = 0):
        super().__init__(seed=seed)
        self.total_nodes = total_nodes
        self.speed_tflops = speed_tflops
        self.queue_noise = queue_noise
        self._noise: dict[str, float] = {}    # job_id -> runtime multiplier

    def render_artifact(self, spec: JobSpec) -> str:
        gpu_line = (f"#SBATCH --gres=gpu:{spec.gpus_per_node}\n"
                    if spec.gpus_per_node else "")
        return SBATCH_TEMPLATE.format(
            name=spec.name, nodes=spec.nodes, cpus=spec.cpus_per_node,
            mem=spec.mem_gb, gpu_line=gpu_line,
            time_min=max(1, spec.time_limit_s // 60), command=spec.command)

    def _on_submit(self, h: JobHandle):
        if self.queue_noise:
            self._noise[h.job_id] = float(
                self.rng.lognormal(0, self.queue_noise))

    def total_capacity(self) -> int:
        return self.total_nodes

    def _try_start(self, handle: JobHandle) -> bool:
        return self.nodes_in_use() + handle.spec.nodes <= self.total_nodes

    def _runtime_s(self, handle: JobHandle) -> float:
        noise = self._noise.get(handle.job_id, 1.0)
        return min(handle.work_s * noise, handle.spec.time_limit_s)

    def prune_terminal(self) -> int:
        n = super().prune_terminal()
        self._noise = {jid: v for jid, v in self._noise.items()
                       if jid in self.jobs}
        return n

    def state_dict(self) -> dict:
        return {**super().state_dict(), "noise": self._noise}

    def load_state(self, s: dict, render_artifacts: bool = True):
        super().load_state(s, render_artifacts)
        self._noise = {jid: float(v)
                       for jid, v in s.get("noise", {}).items()}
