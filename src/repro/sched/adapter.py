"""Scheduler adapter (paper §3.2): one abstraction over SLURM (HPC),
Kubernetes (cloud) and hybrid combinations.

Adapters *generate real artifacts* (sbatch scripts / pod manifests) so the
framework is deployable, and execute them against a simulated backend with a
virtual clock in this offline container (DESIGN.md §2 hardware adaptation).
"""
from __future__ import annotations

import abc
import itertools
from dataclasses import dataclass, field
from enum import Enum


class JobState(str, Enum):
    PENDING = "PENDING"
    RUNNING = "RUNNING"
    COMPLETED = "COMPLETED"
    FAILED = "FAILED"
    PREEMPTED = "PREEMPTED"
    CANCELLED = "CANCELLED"


@dataclass
class JobSpec:
    name: str
    command: str
    nodes: int = 1
    gpus_per_node: int = 0
    cpus_per_node: int = 4
    mem_gb: int = 16
    time_limit_s: int = 3600
    site: str = "hpc"              # routing hint for the hybrid adapter
    preemptible: bool = False


@dataclass
class JobHandle:
    job_id: str
    spec: JobSpec
    state: JobState = JobState.PENDING
    submit_time: float = 0.0
    start_time: float = -1.0
    end_time: float = -1.0
    artifact: str = ""             # generated sbatch script / manifest


class SchedulerAdapter(abc.ABC):
    """submit/poll/cancel + virtual-clock advance."""

    def __init__(self):
        self._ids = itertools.count(1)
        self.jobs: dict[str, JobHandle] = {}
        self.clock: float = 0.0

    @abc.abstractmethod
    def render_artifact(self, spec: JobSpec) -> str: ...

    @abc.abstractmethod
    def _try_start(self, handle: JobHandle) -> bool: ...

    @abc.abstractmethod
    def _runtime_s(self, spec: JobSpec) -> float: ...

    def submit(self, spec: JobSpec) -> JobHandle:
        h = JobHandle(job_id=f"{self.prefix}{next(self._ids)}", spec=spec,
                      submit_time=self.clock,
                      artifact=self.render_artifact(spec))
        self.jobs[h.job_id] = h
        return h

    def poll(self, job_id: str) -> JobState:
        return self.jobs[job_id].state

    def cancel(self, job_id: str):
        h = self.jobs[job_id]
        if h.state in (JobState.PENDING, JobState.RUNNING):
            h.state = JobState.CANCELLED
            h.end_time = self.clock

    def advance(self, dt: float):
        """Advance the virtual clock; start pending jobs, finish running."""
        self.clock += dt
        for h in self.jobs.values():
            if h.state == JobState.PENDING and self._try_start(h):
                h.state = JobState.RUNNING
                h.start_time = self.clock
            if h.state == JobState.RUNNING:
                if self.clock - h.start_time >= self._runtime_s(h.spec):
                    h.state = JobState.COMPLETED
                    h.end_time = self.clock

    def running(self) -> list[JobHandle]:
        return [h for h in self.jobs.values() if h.state == JobState.RUNNING]
