"""Scheduler adapter (paper §3.2): one abstraction over SLURM (HPC),
Kubernetes (cloud) and hybrid combinations.

Adapters *generate real artifacts* (sbatch scripts / pod manifests) so the
framework is deployable, and execute them against a simulated backend with a
virtual clock in this offline container (DESIGN.md §2 hardware adaptation).

The simulation is event-exact and replayable: every random draw a job needs
(queue noise, spot-preemption delay) happens at ``submit`` time, terminal
timestamps are the exact deadlines (``start + runtime``) rather than the
clock at which they were observed, and pending jobs start strictly FIFO.  A
job's whole trajectory is therefore fixed the moment it is submitted — which
is what lets the ``SchedulerBackend`` compute arrival times by stepping a
clone, and lets ``state_dict``/``load_state`` checkpoint mid-flight pools
for bit-identical ``--resume``.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from enum import Enum

import numpy as np


class JobState(str, Enum):
    PENDING = "PENDING"
    RUNNING = "RUNNING"
    COMPLETED = "COMPLETED"
    FAILED = "FAILED"
    PREEMPTED = "PREEMPTED"
    CANCELLED = "CANCELLED"


TERMINAL_STATES = (JobState.COMPLETED, JobState.FAILED, JobState.PREEMPTED,
                   JobState.CANCELLED)


@dataclass
class JobSpec:
    name: str
    command: str
    nodes: int = 1
    gpus_per_node: int = 0
    cpus_per_node: int = 4
    mem_gb: int = 16
    time_limit_s: int = 3600
    site: str = "hpc"              # routing hint for the hybrid adapter
    preemptible: bool = False


@dataclass
class JobHandle:
    job_id: str
    spec: JobSpec
    state: JobState = JobState.PENDING
    submit_time: float = 0.0
    start_time: float = -1.0
    end_time: float = -1.0
    artifact: str = ""             # generated sbatch script / manifest
    work_s: float = 60.0           # workload, attached at submit time


class SchedulerAdapter(abc.ABC):
    """submit/poll/cancel + virtual-clock advance."""

    def __init__(self, seed: int = 0):
        self._next_id = 1
        self.jobs: dict[str, JobHandle] = {}
        self.clock: float = 0.0
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    @abc.abstractmethod
    def render_artifact(self, spec: JobSpec) -> str: ...

    @abc.abstractmethod
    def _try_start(self, handle: JobHandle) -> bool: ...

    @abc.abstractmethod
    def _runtime_s(self, handle: JobHandle) -> float: ...

    def _finish_deadline(self, h: JobHandle) -> tuple[float, JobState]:
        """(exact sim-time the running job leaves the node, terminal state)."""
        return h.start_time + self._runtime_s(h), JobState.COMPLETED

    def _on_submit(self, h: JobHandle):
        """Hook: draw any per-job randomness NOW so replay is order-exact."""

    # ------------------------------------------------------------ public API
    def submit(self, spec: JobSpec, work_s: float | None = None) -> JobHandle:
        h = JobHandle(job_id=f"{self.prefix}{self._next_id}", spec=spec,
                      submit_time=self.clock,
                      artifact=self.render_artifact(spec))
        self._next_id += 1
        if work_s is not None:
            h.work_s = float(work_s)
        self.jobs[h.job_id] = h
        self._on_submit(h)
        return h

    def set_workload(self, job_id: str, seconds: float):
        self.jobs[job_id].work_s = float(seconds)

    def poll(self, job_id: str) -> JobState:
        return self.jobs[job_id].state

    def cancel(self, job_id: str):
        h = self.jobs[job_id]
        if h.state in (JobState.PENDING, JobState.RUNNING):
            h.state = JobState.CANCELLED
            h.end_time = self.clock

    def advance(self, dt: float):
        """Advance the virtual clock; start pending jobs, finish running."""
        self.advance_to(self.clock + dt)

    def advance_to(self, t: float):
        """Advance to absolute sim-time ``t`` (no-op move if in the past),
        stepping through every intermediate job-state transition so PENDING
        jobs start at the exact instant capacity frees — not quantised to
        the destination time.  This is what keeps the real pool's
        trajectory identical to the ``SchedulerBackend`` lookahead clone's
        (and makes queue-wait accounting exact under contention)."""
        while True:
            nxt = self.next_event_time()
            if nxt is None or nxt > t or nxt <= self.clock:
                break
            self.clock = nxt
            self._settle()
        self.clock = max(self.clock, t)
        self._settle()

    def _settle(self):
        for h in self.jobs.values():
            if h.state == JobState.RUNNING:
                self._maybe_finish(h)
        # strict FIFO: a pending job can start only once every job submitted
        # before it has started — later submissions never backfill ahead,
        # which is what makes start times computable at submit time
        for h in self.jobs.values():
            if h.state == JobState.PENDING:
                if self._try_start(h):
                    h.state = JobState.RUNNING
                    h.start_time = self.clock
                    self._maybe_finish(h)
                else:
                    break

    def _maybe_finish(self, h: JobHandle):
        t, state = self._finish_deadline(h)
        if self.clock >= t:
            h.state = state
            h.end_time = t

    def next_event_time(self) -> float | None:
        """Earliest future job-state transition (None when nothing runs)."""
        deadlines = [self._finish_deadline(h)[0] for h in self.jobs.values()
                     if h.state == JobState.RUNNING]
        deadlines = [t for t in deadlines if t > self.clock]
        return min(deadlines) if deadlines else None

    # ---------------------------------------------------------- capacity API
    def running(self) -> list[JobHandle]:
        return [h for h in self.jobs.values() if h.state == JobState.RUNNING]

    def pending(self) -> list[JobHandle]:
        return [h for h in self.jobs.values() if h.state == JobState.PENDING]

    def nodes_in_use(self) -> int:
        return sum(h.spec.nodes for h in self.running())

    def committed_nodes(self) -> int:
        """Nodes claimed by running AND queued work (overflow decisions)."""
        return self.nodes_in_use() + sum(h.spec.nodes for h in self.pending())

    @abc.abstractmethod
    def total_capacity(self) -> int:
        """Node budget this pool can ever offer."""

    def prune_terminal(self) -> int:
        """Drop finished jobs from the active table (they no longer affect
        the simulation); returns how many were pruned."""
        gone = [jid for jid, h in self.jobs.items()
                if h.state in TERMINAL_STATES]
        for jid in gone:
            del self.jobs[jid]
        return len(gone)

    # -------------------------------------------------- checkpointable state
    _SPEC_FIELDS = ("name", "command", "nodes", "gpus_per_node",
                    "cpus_per_node", "mem_gb", "time_limit_s", "site",
                    "preemptible")
    _JOB_FIELDS = ("job_id", "state", "submit_time", "start_time", "end_time",
                   "work_s")

    def state_dict(self) -> dict:
        return {
            "clock": self.clock,
            "next_id": self._next_id,
            "rng": self.rng.bit_generator.state,
            "jobs": [{**{f: getattr(h, f) for f in self._JOB_FIELDS},
                      "state": h.state.value,
                      "spec": {f: getattr(h.spec, f)
                               for f in self._SPEC_FIELDS}}
                     for h in self.jobs.values()],
        }

    def load_state(self, s: dict, render_artifacts: bool = True):
        """``render_artifacts=False`` skips re-rendering sbatch/manifest
        strings — lookahead clones never read them."""
        self.clock = float(s["clock"])
        self._next_id = int(s["next_id"])
        self.rng.bit_generator.state = s["rng"]
        self.jobs = {}
        for j in s["jobs"]:
            spec = JobSpec(**j["spec"])
            h = JobHandle(job_id=j["job_id"], spec=spec,
                          state=JobState(j["state"]),
                          submit_time=j["submit_time"],
                          start_time=j["start_time"], end_time=j["end_time"],
                          artifact=(self.render_artifact(spec)
                                    if render_artifacts else ""),
                          work_s=j["work_s"])
            self.jobs[h.job_id] = h
