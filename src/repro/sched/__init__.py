from repro.sched.adapter import JobSpec, JobHandle, JobState, SchedulerAdapter  # noqa: F401
from repro.sched.slurm import SlurmAdapter  # noqa: F401
from repro.sched.k8s import K8sAdapter, pod_manifest  # noqa: F401
from repro.sched.hybrid import HybridAdapter  # noqa: F401
