"""Kubernetes adapter: renders pod manifests (JSON form of the YAML);
simulates a cluster with autoscaling node groups and spot preemption."""
from __future__ import annotations

import json

import numpy as np

from repro.sched.adapter import JobHandle, JobSpec, JobState, SchedulerAdapter


def pod_manifest(spec: JobSpec) -> dict:
    res = {"cpu": str(spec.cpus_per_node), "memory": f"{spec.mem_gb}Gi"}
    if spec.gpus_per_node:
        res["nvidia.com/gpu"] = str(spec.gpus_per_node)
    return {
        "apiVersion": "v1",
        "kind": "Pod",
        "metadata": {"name": spec.name,
                     "labels": {"app": "fl-client",
                                "preemptible": str(spec.preemptible).lower()}},
        "spec": {
            "restartPolicy": "OnFailure",
            "containers": [{
                "name": "fl-worker",
                "image": "repro/fl-worker:latest",
                "command": ["/bin/sh", "-c", spec.command],
                "resources": {"requests": res, "limits": res},
            }],
            **({"tolerations": [{"key": "cloud.google.com/gke-spot",
                                 "operator": "Equal", "value": "true",
                                 "effect": "NoSchedule"}]}
               if spec.preemptible else {}),
        },
    }


class K8sAdapter(SchedulerAdapter):
    prefix = "pod-"

    def __init__(self, initial_nodes: int = 10, max_nodes: int = 60,
                 scale_step: int = 5, preempt_prob_per_min: float = 0.0,
                 seed: int = 0):
        super().__init__()
        self.nodes = initial_nodes
        self.max_nodes = max_nodes
        self.scale_step = scale_step
        self.preempt_prob_per_min = preempt_prob_per_min
        self.rng = np.random.default_rng(seed)
        self._work: dict[str, float] = {}

    def render_artifact(self, spec: JobSpec) -> str:
        return json.dumps(pod_manifest(spec), indent=2)

    def set_workload(self, job_id: str, seconds: float):
        self._work[job_id] = seconds

    def _pods_running(self) -> int:
        return len(self.running())

    def _try_start(self, handle: JobHandle) -> bool:
        if self._pods_running() < self.nodes:
            return True
        # autoscale
        if self.nodes < self.max_nodes:
            self.nodes = min(self.nodes + self.scale_step, self.max_nodes)
            return self._pods_running() < self.nodes
        return False

    def _runtime_s(self, spec: JobSpec) -> float:
        for jid, h in self.jobs.items():
            if h.spec is spec:
                return min(self._work.get(jid, 60.0), spec.time_limit_s)
        return 60.0

    def advance(self, dt: float):
        super().advance(dt)
        if self.preempt_prob_per_min:
            p = self.preempt_prob_per_min * dt / 60.0
            for h in self.running():
                if h.spec.preemptible and self.rng.random() < p:
                    h.state = JobState.PREEMPTED
                    h.end_time = self.clock
