"""Kubernetes adapter: renders pod manifests (JSON form of the YAML);
simulates a cluster with autoscaling node groups and spot preemption.

Spot preemption is modelled as a memoryless reclaim: each preemptible pod
draws an exponential time-to-preemption (rate ``preempt_prob_per_min`` per
minute) at SUBMIT time, applied from the moment the pod starts.  In the
small-step limit this is the same process as a per-tick Bernoulli draw, but
the strike time is an exact, replayable event — which is what lets the
``SchedulerBackend`` surface adapter preemptions into the orchestrator's
fault stream and checkpoint mid-flight pools."""
from __future__ import annotations

import json

from repro.sched.adapter import JobHandle, JobSpec, JobState, SchedulerAdapter


def pod_manifest(spec: JobSpec) -> dict:
    res = {"cpu": str(spec.cpus_per_node), "memory": f"{spec.mem_gb}Gi"}
    if spec.gpus_per_node:
        res["nvidia.com/gpu"] = str(spec.gpus_per_node)
    return {
        "apiVersion": "v1",
        "kind": "Pod",
        "metadata": {"name": spec.name,
                     "labels": {"app": "fl-client",
                                "preemptible": str(spec.preemptible).lower()}},
        "spec": {
            "restartPolicy": "OnFailure",
            "containers": [{
                "name": "fl-worker",
                "image": "repro/fl-worker:latest",
                "command": ["/bin/sh", "-c", spec.command],
                "resources": {"requests": res, "limits": res},
            }],
            **({"tolerations": [{"key": "cloud.google.com/gke-spot",
                                 "operator": "Equal", "value": "true",
                                 "effect": "NoSchedule"}]}
               if spec.preemptible else {}),
        },
    }


class K8sAdapter(SchedulerAdapter):
    prefix = "pod-"

    def __init__(self, initial_nodes: int = 10, max_nodes: int = 60,
                 scale_step: int = 5, preempt_prob_per_min: float = 0.0,
                 seed: int = 0):
        super().__init__(seed=seed)
        self.initial_nodes = initial_nodes   # construction-time level (the
        #                                      checkpoint-compat config key)
        self.nodes = initial_nodes           # current autoscaled level
        self.max_nodes = max_nodes
        self.scale_step = scale_step
        self.preempt_prob_per_min = preempt_prob_per_min
        self._preempt_delay: dict[str, float] = {}  # job_id -> s after start

    def render_artifact(self, spec: JobSpec) -> str:
        return json.dumps(pod_manifest(spec), indent=2)

    def _on_submit(self, h: JobHandle):
        if self.preempt_prob_per_min and h.spec.preemptible:
            self._preempt_delay[h.job_id] = float(
                self.rng.exponential(60.0 / self.preempt_prob_per_min))

    def _pods_running(self) -> int:
        return len(self.running())

    def total_capacity(self) -> int:
        return self.max_nodes

    def nodes_in_use(self) -> int:
        return self._pods_running()

    def committed_nodes(self) -> int:
        return self._pods_running() + len(self.pending())

    def _try_start(self, handle: JobHandle) -> bool:
        # autoscale as far as needed (and allowed) in one step, so a start
        # is never delayed purely by scale-step quantisation
        while self._pods_running() >= self.nodes and self.nodes < self.max_nodes:
            self.nodes = min(self.nodes + self.scale_step, self.max_nodes)
        return self._pods_running() < self.nodes

    def _runtime_s(self, handle: JobHandle) -> float:
        return min(handle.work_s, handle.spec.time_limit_s)

    def _finish_deadline(self, h: JobHandle) -> tuple[float, JobState]:
        done = h.start_time + self._runtime_s(h)
        strike = self._preempt_delay.get(h.job_id)
        if strike is not None and h.start_time + strike < done:
            return h.start_time + strike, JobState.PREEMPTED
        return done, JobState.COMPLETED

    def prune_terminal(self) -> int:
        n = super().prune_terminal()
        self._preempt_delay = {jid: v
                               for jid, v in self._preempt_delay.items()
                               if jid in self.jobs}
        return n

    def state_dict(self) -> dict:
        return {**super().state_dict(), "nodes": self.nodes,
                "preempt_delay": self._preempt_delay}

    def load_state(self, s: dict, render_artifacts: bool = True):
        super().load_state(s, render_artifacts)
        self.nodes = int(s.get("nodes", self.nodes))
        self._preempt_delay = {jid: float(v)
                               for jid, v in s.get("preempt_delay",
                                                   {}).items()}
