"""Hybrid adapter: routes jobs across SLURM (HPC) + K8s (cloud) and provides
the elastic mixed-infrastructure coordination of paper §3.2."""
from __future__ import annotations

from repro.sched.adapter import JobHandle, JobSpec, JobState, SchedulerAdapter
from repro.sched.k8s import K8sAdapter
from repro.sched.slurm import SlurmAdapter


class HybridAdapter:
    """Not a SchedulerAdapter subclass — it owns one adapter per site and
    presents the same submit/poll/cancel/advance surface."""

    def __init__(self, slurm: SlurmAdapter | None = None,
                 k8s: K8sAdapter | None = None,
                 overflow_to_cloud: bool = True):
        self.slurm = slurm or SlurmAdapter()
        self.k8s = k8s or K8sAdapter()
        self.overflow_to_cloud = overflow_to_cloud
        self._route: dict[str, SchedulerAdapter] = {}

    @property
    def clock(self) -> float:
        return max(self.slurm.clock, self.k8s.clock)

    def submit(self, spec: JobSpec) -> JobHandle:
        target = self.slurm if spec.site == "hpc" else self.k8s
        # elastic overflow: if the HPC queue is saturated, burst to cloud
        if (target is self.slurm and self.overflow_to_cloud
                and self.slurm._nodes_in_use() + spec.nodes > self.slurm.total_nodes):
            target = self.k8s
        h = target.submit(spec)
        self._route[h.job_id] = target
        return h

    def set_workload(self, job_id: str, seconds: float):
        self._route[job_id].set_workload(job_id, seconds)

    def poll(self, job_id: str) -> JobState:
        return self._route[job_id].poll(job_id)

    def cancel(self, job_id: str):
        self._route[job_id].cancel(job_id)

    def advance(self, dt: float):
        self.slurm.advance(dt)
        self.k8s.advance(dt)

    def running(self):
        return self.slurm.running() + self.k8s.running()
