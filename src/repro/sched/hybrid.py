"""Hybrid adapter: routes jobs across SLURM (HPC) + K8s (cloud) and provides
the elastic mixed-infrastructure coordination of paper §3.2."""
from __future__ import annotations

from repro.sched.adapter import JobHandle, JobSpec, JobState, SchedulerAdapter
from repro.sched.k8s import K8sAdapter
from repro.sched.slurm import SlurmAdapter


class HybridAdapter:
    """Not a SchedulerAdapter subclass — it owns one adapter per site and
    presents the same submit/poll/cancel/advance surface."""

    def __init__(self, slurm: SlurmAdapter | None = None,
                 k8s: K8sAdapter | None = None,
                 overflow_to_cloud: bool = True):
        self.slurm = slurm or SlurmAdapter()
        self.k8s = k8s or K8sAdapter()
        self.overflow_to_cloud = overflow_to_cloud
        self._route: dict[str, SchedulerAdapter] = {}

    @property
    def clock(self) -> float:
        return max(self.slurm.clock, self.k8s.clock)

    def site_of(self, job_id: str) -> str:
        """Site the job was actually PLACED on (after elastic overflow)."""
        return "hpc" if self._route[job_id] is self.slurm else "cloud"

    def submit(self, spec: JobSpec, work_s: float | None = None) -> JobHandle:
        target = self.slurm if spec.site == "hpc" else self.k8s
        # elastic overflow: if the HPC partition cannot absorb the job —
        # counting queued work, not just running jobs — burst to cloud
        if (target is self.slurm and self.overflow_to_cloud
                and self.slurm.committed_nodes() + spec.nodes
                > self.slurm.total_capacity()):
            target = self.k8s
        h = target.submit(spec, work_s=work_s)
        self._route[h.job_id] = target
        return h

    def set_workload(self, job_id: str, seconds: float):
        self._route[job_id].set_workload(job_id, seconds)

    def poll(self, job_id: str) -> JobState:
        return self._route[job_id].poll(job_id)

    def cancel(self, job_id: str):
        self._route[job_id].cancel(job_id)

    def advance(self, dt: float):
        self.advance_to(self.clock + dt)

    def advance_to(self, t: float):
        self.slurm.advance_to(t)
        self.k8s.advance_to(t)

    def next_event_time(self) -> float | None:
        ts = [t for t in (self.slurm.next_event_time(),
                          self.k8s.next_event_time()) if t is not None]
        return min(ts) if ts else None

    def running(self):
        return self.slurm.running() + self.k8s.running()

    def prune_terminal(self) -> int:
        n = self.slurm.prune_terminal() + self.k8s.prune_terminal()
        live = set(self.slurm.jobs) | set(self.k8s.jobs)
        self._route = {jid: a for jid, a in self._route.items()
                       if jid in live}
        return n

    # -------------------------------------------------- checkpointable state
    def state_dict(self) -> dict:
        return {"slurm": self.slurm.state_dict(),
                "k8s": self.k8s.state_dict(),
                "route": {jid: ("hpc" if a is self.slurm else "cloud")
                          for jid, a in self._route.items()}}

    def load_state(self, s: dict, render_artifacts: bool = True):
        self.slurm.load_state(s["slurm"], render_artifacts)
        self.k8s.load_state(s["k8s"], render_artifacts)
        self._route = {jid: (self.slurm if site == "hpc" else self.k8s)
                       for jid, site in s["route"].items()}

    def config_dict(self) -> dict:
        """Constructor arguments that rebuild an identically-shaped pool —
        the SchedulerBackend's clone()/checkpoint-compat key."""
        return {
            "slurm": {"total_nodes": self.slurm.total_nodes,
                      "speed_tflops": self.slurm.speed_tflops,
                      "queue_noise": self.slurm.queue_noise,
                      "seed": self.slurm.seed},
            "k8s": {"initial_nodes": self.k8s.initial_nodes,
                    "max_nodes": self.k8s.max_nodes,
                    "scale_step": self.k8s.scale_step,
                    "preempt_prob_per_min": self.k8s.preempt_prob_per_min,
                    "seed": self.k8s.seed},
            "overflow_to_cloud": self.overflow_to_cloud,
        }

    def clone(self) -> "HybridAdapter":
        cfg = self.config_dict()
        twin = HybridAdapter(slurm=SlurmAdapter(**cfg["slurm"]),
                             k8s=K8sAdapter(**cfg["k8s"]),
                             overflow_to_cloud=cfg["overflow_to_cloud"])
        twin.load_state(self.state_dict(), render_artifacts=False)
        return twin
