"""repro: Federated Learning for heterogeneous HPC + cloud (Ghimire et al.
2025), reproduced as a production multi-pod JAX/TPU framework.

See DESIGN.md for architecture, EXPERIMENTS.md for results.
"""
__version__ = "1.0.0"
