"""AsyncOrchestrator event-queue semantics: deterministic ordering under a
fixed seed, K-arrival and T-timeout commit triggers, staleness bookkeeping,
comm accounting, and barrier-vs-buffered throughput on a straggler fleet."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AsyncConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (AsyncOrchestrator, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet)

CFG = CNNConfig("tiny-cnn", (28, 28, 1), 9, channels=(4, 8), dense=32)


def make_orch(seed=0, n_clients=8, buffer_size=4, commit_timeout=0.0,
              max_concurrency=6, sigma=0.5, **async_kw):
    data = medmnist_like(n=600, seed=seed)
    parts = partition_dirichlet(data.y, n_clients, alpha=0.5, seed=seed)
    fed = FederatedDataset(data, parts, seed=seed)
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(seed))
    fleet = make_hybrid_fleet(n_clients // 2, n_clients - n_clients // 2,
                              seed=seed, data_sizes=[len(p) for p in parts])
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=n_clients, local_steps=1,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=buffer_size,
                              commit_timeout_s=commit_timeout,
                              max_concurrency=max_concurrency, **async_kw),
        straggler=StragglerPolicy(contention_sigma=sigma),
        batch_size=8, flops_per_client_round=2e12, seed=seed)
    return orch, params


def test_event_queue_deterministic_under_fixed_seed():
    traces = []
    for _ in range(2):
        orch, params = make_orch(seed=7)
        orch.run(params, num_commits=5)
        traces.append([(l.commit, round(l.sim_time, 9), l.n_updates,
                        l.mean_staleness, round(l.client_loss, 7),
                        round(l.delta_norm, 7)) for l in orch.logs])
    assert traces[0] == traces[1]
    assert len(traces[0]) == 5


def test_commits_every_k_arrivals():
    orch, params = make_orch(buffer_size=3, commit_timeout=0.0)
    orch.run(params, num_commits=4)
    assert orch.version == 4
    assert all(l.n_updates == 3 for l in orch.logs)      # K-arrival trigger
    assert not any(l.timeout_commit for l in orch.logs)
    assert orch.updates_applied == 12
    # sim clock advanced and commits are time-ordered
    times = [l.sim_time for l in orch.logs]
    assert times == sorted(times) and times[-1] > 0


def test_timeout_commits_partial_buffer():
    # K unreachably large -> only the T-timeout can trigger commits
    orch, params = make_orch(buffer_size=64, commit_timeout=1.0,
                             max_concurrency=4)
    orch.run(params, num_commits=3)
    assert orch.version == 3
    assert all(l.timeout_commit for l in orch.logs)
    assert all(0 < l.n_updates < 64 for l in orch.logs)
    # timeout commits are stamped on the T grid, not at arrival times
    for prev, cur in zip([0.0] + [l.sim_time for l in orch.logs],
                         [l.sim_time for l in orch.logs]):
        assert cur >= prev + 1.0 - 1e-9


def test_staleness_accrues_and_is_bounded():
    orch, params = make_orch(buffer_size=2, max_concurrency=8, sigma=0.8,
                             max_staleness=50)
    orch.run(params, num_commits=12)
    stal = [l.mean_staleness for l in orch.logs]
    assert max(stal) > 0            # concurrency + commits => staleness
    assert max(l.max_staleness for l in orch.logs) <= 50


def test_very_stale_updates_are_dropped():
    orch, params = make_orch(buffer_size=2, max_concurrency=8, sigma=1.0,
                             max_staleness=0)
    orch.run(params, num_commits=10)
    # with max_staleness=0 any update that saw a commit in flight is dropped
    assert orch.dropped_stale > 0
    assert all(l.max_staleness == 0 for l in orch.logs)


def test_comm_accounting_logs_every_update():
    orch, params = make_orch(buffer_size=3)
    orch.run(params, num_commits=3)
    ups = [r for r in orch.comm.records if r.direction == "up"]
    downs = [r for r in orch.comm.records if r.direction == "down"]
    # every arriving update paid an uplink (even ones later dropped as too
    # stale); every dispatch paid a downlink
    assert len(ups) == (orch.updates_applied + len(orch._buffer)
                        + orch.dropped_stale)
    assert len(downs) >= len(ups)
    assert all(r.nbytes > 0 and r.seconds > 0 for r in ups)


def test_params_actually_move():
    orch, params = make_orch(buffer_size=3)
    p2, _ = orch.run(params, num_commits=3)
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


def test_continuation_run_respects_concurrency_cap():
    """A budget-limited run pushes in-flight events back; resuming must top
    up to max_concurrency, not dispatch a whole new batch on top."""
    orch, params = make_orch(buffer_size=3, max_concurrency=4)
    p1, st = orch.run(params, num_commits=1000, max_sim_time=0.05)
    assert orch._inflight                      # paused with work in flight
    orch.run(p1, num_commits=orch.version + 2, server_state=st)
    assert len(orch._inflight) <= 4


def test_async_beats_sync_barrier_on_straggler_fleet():
    """Core throughput claim, in miniature: on a heterogeneous fleet with
    heavy contention noise, buffered-async applies >= 1.5x more client
    updates per simulated second than the barrier loop."""
    seed, n = 3, 8
    data = medmnist_like(n=600, seed=seed)
    parts = partition_dirichlet(data.y, n, alpha=0.5, seed=seed)
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(seed))

    def fleet():
        return make_hybrid_fleet(n // 2, n - n // 2, seed=seed,
                                 data_sizes=[len(p) for p in parts])

    sync = Orchestrator(
        fleet=fleet(), fed_data=FederatedDataset(data, parts, seed=seed),
        loss_fn=model.loss_fn,
        fl=FLConfig(num_clients=n, local_steps=1, client_lr=0.05),
        straggler=StragglerPolicy(contention_sigma=0.6),
        batch_size=8, flops_per_client_round=2e12, seed=seed)
    sync.run(params, 3)
    sync_updates = sum(l.participated for l in sync.logs)
    sync_tput = sync_updates / sync.virtual_clock

    anc = AsyncOrchestrator(
        fleet=fleet(), fed_data=FederatedDataset(data, parts, seed=seed),
        loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=n, local_steps=1,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=4, max_concurrency=n),
        straggler=StragglerPolicy(contention_sigma=0.6),
        batch_size=8, flops_per_client_round=2e12, seed=seed)
    anc.run(params, num_commits=6)
    assert anc.updates_per_sim_second >= 1.5 * sync_tput
