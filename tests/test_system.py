"""End-to-end system behaviour: the full orchestrated FL loop (Algorithm 1 +
§4 optimizations) trains real models on non-IID synthetic data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import CompressionConfig, FLConfig
from repro.data import (FederatedDataset, cifar10_like, partition_by_class,
                        partition_by_group, shakespeare_like)
from repro.models import build_model
from repro.models.cnn import CNN, CNNConfig
from repro.configs import get_config
from repro.orchestrator import (FaultConfig, Orchestrator, StragglerPolicy,
                                make_hybrid_fleet)

TINY_CNN = CNNConfig("tiny-cnn", (32, 32, 3), 10, channels=(8, 16), dense=64)


def make_orch(fl=None, straggler=None, faults=None, seed=0, n=1200,
              clients=8, sel="adaptive"):
    # lower noise than the benchmark default: these are fast smoke-scale
    # runs (10-14 rounds, tiny CNN) that must visibly learn
    ds = cifar10_like(n=n, seed=seed, noise=0.6)
    parts = partition_by_class(ds.y, clients, 2, seed=seed)
    fed = FederatedDataset(ds, parts)
    model = CNN(TINY_CNN)
    params = model.init(jax.random.PRNGKey(seed))
    fleet = make_hybrid_fleet(clients // 2, clients // 2,
                              data_sizes=[len(p) for p in parts])
    eval_batch = jax.tree.map(jnp.asarray, fed.eval_batch(384))
    acc_fn = jax.jit(model.accuracy)
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=fl or FLConfig(num_clients=4, local_steps=3, client_lr=0.08),
        selection_name=sel,
        straggler=straggler or StragglerPolicy(),
        faults=faults or FaultConfig(),
        batch_size=16, flops_per_client_round=5e11,
        eval_fn=lambda p: acc_fn(p, eval_batch), eval_every=100)
    return orch, params, model


class TestEndToEnd:
    def test_fl_training_improves_accuracy(self):
        orch, params, _ = make_orch()
        params, _ = orch.run(params, 10)
        accs = [l.eval_metric for l in orch.logs if np.isfinite(l.eval_metric)]
        assert accs[0] < 0.3            # starts at chance-ish
        assert accs[-1] > 0.55, accs    # learns under pathological non-IID

    def test_dropout_resilience(self):
        """Paper §5.4: 20% dropout -> training still converges (the
        quantitative <1.8%-gap claim is reproduced in benchmarks/).  At
        smoke scale, eval accuracy oscillates under non-IID + dropout, so
        the convergence signal asserted here is the training loss."""
        orch, params, _ = make_orch(
            faults=FaultConfig(dropout_prob=0.2), seed=1)
        params, _ = orch.run(params, 14)
        losses = [l.client_loss for l in orch.logs]
        assert np.mean(losses[-3:]) < losses[0] - 0.5, losses
        assert any(l.participated < 4 for l in orch.logs)  # drops happened

    def test_compression_does_not_break_convergence(self):
        # 14 rounds, not 10: at 10 this config sits right on the 0.5
        # threshold (0.497 at round 9, seed 2) — one more eval point shows
        # it clearly converging (0.89 by round 13)
        fl = FLConfig(num_clients=4, local_steps=3, client_lr=0.08,
                      compression=CompressionConfig(quantize_bits=8,
                                                    topk_frac=0.25))
        orch, params, _ = make_orch(fl=fl, seed=2)
        params, _ = orch.run(params, 14)
        accs = [l.eval_metric for l in orch.logs if np.isfinite(l.eval_metric)]
        assert accs[-1] > 0.5, accs

    def test_fastest_k_reduces_round_duration(self):
        orch1, params, _ = make_orch(seed=3)
        orch1.run(params, 6)
        orch2, params2, _ = make_orch(
            straggler=StragglerPolicy(fastest_k=2), seed=3)
        orch2.run(params2, 6)
        d1 = np.mean([l.duration_s for l in orch1.logs])
        d2 = np.mean([l.duration_s for l in orch2.logs])
        assert d2 < d1

    def test_checkpoint_resume(self, tmp_path):
        orch, params, _ = make_orch(seed=4)
        orch.checkpoint_mgr = CheckpointManager(tmp_path)
        orch.checkpoint_every = 2
        params, sstate = orch.run(params, 5)
        p2, s2, meta = orch.checkpoint_mgr.restore(params)
        assert meta["round"] == 4
        # resumed params load bit-exact into the round step
        orch.run_round(meta["round"] + 1, jax.tree.map(jnp.asarray, p2),
                       sstate if s2 is None else s2)


class TestCharLM:
    def test_federated_charlm_loss_decreases(self):
        ds = shakespeare_like(n_seqs=600, seq_len=32, n_speakers=12)
        parts = partition_by_group(ds.y, 6)
        fed = FederatedDataset(ds, parts)
        cfg = get_config("paper-charlm").replace(n_layers=2, d_model=128,
                                                 d_ff=256)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        fleet = make_hybrid_fleet(3, 3, data_sizes=[len(p) for p in parts])
        orch = Orchestrator(
            fleet=fleet, fed_data=fed, loss_fn=m.loss_fn,
            fl=FLConfig(num_clients=3, local_steps=2, client_lr=0.3),
            batch_size=8, flops_per_client_round=1e11)
        params, _ = orch.run(params, 8)
        losses = [l.client_loss for l in orch.logs]
        assert losses[-1] < losses[0] - 0.3, losses
