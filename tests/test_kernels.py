"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(64,), (8, 32), (3, 1000), (2, 7, 129), (4096,)]
DTYPES = [jnp.float32, jnp.bfloat16]


def rand(shape, dtype, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape), dtype)


class TestQuantize:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("bits", [8, 4])
    def test_matches_ref(self, shape, dtype, bits):
        x = rand(shape, dtype, seed=hash((shape, bits)) % 2**31)
        got = np.asarray(ops.quantize_dequant(x, bits=bits, block=128), np.float32)
        want = np.asarray(ref.quantize_dequant_ref(x, bits=bits, block=128),
                          np.float32)
        # contract: equal up to (a) 1-ulp float noise from different fusion
        # of y*scale, and (b) rare round-to-nearest .5 boundary flips, which
        # are bounded by one quantization step.
        step = np.abs(np.asarray(x, np.float32)).max() / (2 ** (bits - 1) - 1)
        close = np.abs(got - want) <= 1e-5 * np.abs(want) + 1e-6
        boundary = np.abs(got - want) <= step * 1.001
        assert (close | boundary).all()
        assert close.mean() >= 0.99   # boundary flips must stay rare

    def test_error_bound(self):
        x = rand((4096,), jnp.float32)
        y = ops.quantize_dequant(x, bits=8, block=256)
        # per-block max error <= scale/2 = max|x| / qmax / 2
        xb = np.asarray(x).reshape(-1, 256)
        yb = np.asarray(y).reshape(-1, 256)
        bound = np.abs(xb).max(-1, keepdims=True) / 127 * 0.5 + 1e-7
        assert (np.abs(xb - yb) <= bound).all()

    def test_zero_block(self):
        x = jnp.zeros((512,), jnp.float32)
        np.testing.assert_array_equal(ops.quantize_dequant(x, bits=8), x)


class TestTopK:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("k", [1, 8, 64])
    def test_matches_ref(self, shape, k):
        x = rand(shape, jnp.float32, seed=hash((shape, k)) % 2**31)
        got = ops.topk_sparsify(x, k=k, block=128)
        want = ref.topk_sparsify_ref(x, k=k, block=128)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_keeps_k_per_block(self):
        x = rand((2048,), jnp.float32, seed=7)
        y = np.asarray(ops.topk_sparsify(x, k=16, block=256)).reshape(-1, 256)
        assert ((y != 0).sum(-1) == 16).all()

    def test_kept_values_unchanged(self):
        x = rand((512,), jnp.float32, seed=9)
        y = np.asarray(ops.topk_sparsify(x, k=32, block=256))
        nz = y != 0
        np.testing.assert_array_equal(y[nz], np.asarray(x)[nz])


class TestFedProx:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("mu", [0.0, 0.01, 1.0])
    def test_matches_ref(self, shape, dtype, mu):
        w = rand(shape, dtype, 1)
        g = rand(shape, dtype, 2)
        w0 = rand(shape, dtype, 3)
        got = ops.fedprox_update(w, g, w0, lr=0.1, mu=mu)
        want = ref.fedprox_update_ref(w, g, w0, 0.1, mu)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-6)

    def test_mu_zero_is_sgd(self):
        w, g = rand((100,), jnp.float32, 1), rand((100,), jnp.float32, 2)
        got = ops.fedprox_update(w, g, jnp.zeros_like(w), lr=0.5, mu=0.0)
        np.testing.assert_allclose(got, w - 0.5 * g, rtol=1e-6)


class TestSelectiveScan:
    @pytest.mark.parametrize("B,L,D,N", [(1, 8, 128, 4), (2, 16, 256, 8),
                                         (3, 32, 384, 16)])
    def test_matches_ref(self, B, L, D, N):
        rng = np.random.default_rng(L * D)
        a = jnp.asarray(rng.uniform(0.3, 1.0, (B, L, D, N)), jnp.float32)
        b = jnp.asarray(rng.normal(0, 0.1, (B, L, D, N)), jnp.float32)
        h0 = jnp.asarray(rng.normal(0, 1, (B, D, N)), jnp.float32)
        hs, hl = ops.selective_scan_chunk(a, b, h0)
        hs_r, hl_r = ref.selective_scan_chunk_ref(a, b, h0)
        np.testing.assert_allclose(hs, hs_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(hl, hl_r, rtol=1e-5, atol=1e-5)

    def test_vjp_matches_ref(self):
        rng = np.random.default_rng(0)
        B, L, D, N = 2, 12, 128, 4
        a = jnp.asarray(rng.uniform(0.5, 1.0, (B, L, D, N)), jnp.float32)
        b = jnp.asarray(rng.normal(0, 0.1, (B, L, D, N)), jnp.float32)
        h0 = jnp.asarray(rng.normal(0, 1, (B, D, N)), jnp.float32)

        def loss(fn):
            return lambda a, b, h0: (
                (fn(a, b, h0)[0] * jnp.arange(L)[None, :, None, None]).sum()
                + fn(a, b, h0)[1].sum())

        g1 = jax.grad(loss(ops.selective_scan_chunk), argnums=(0, 1, 2))(a, b, h0)
        g2 = jax.grad(loss(ref.selective_scan_chunk_ref), argnums=(0, 1, 2))(a, b, h0)
        for x1, x2 in zip(g1, g2):
            np.testing.assert_allclose(x1, x2, rtol=1e-4, atol=1e-5)

    def test_sequential_semantics(self):
        # tiny hand-rolled loop equals the kernel
        B, L, D, N = 1, 5, 128, 2
        rng = np.random.default_rng(5)
        a = rng.uniform(0.2, 0.9, (B, L, D, N)).astype(np.float32)
        b = rng.normal(0, 1, (B, L, D, N)).astype(np.float32)
        h0 = rng.normal(0, 1, (B, D, N)).astype(np.float32)
        hs, hl = ops.selective_scan_chunk(jnp.asarray(a), jnp.asarray(b),
                                          jnp.asarray(h0))
        h = h0.copy()
        for t in range(L):
            h = a[:, t] * h + b[:, t]
            np.testing.assert_allclose(hs[:, t], h, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(hl, h, rtol=1e-5, atol=1e-6)
