"""Validate the analytic roofline cost model against XLA's cost_analysis on
UNROLLED (scan-free) builds — the one configuration where HloCostAnalysis
measures true totals (while bodies are otherwise counted once)."""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import costmodel as cm  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.transformer import LM  # noqa: E402


def measured_fwd_flops(cfg, B, S):
    m = LM(cfg, unroll=True)
    params = jax.eval_shape(lambda: m.param_specs())  # not needed; use specs
    param_sds = m.param_specs()
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    lowered = jax.jit(lambda p, b: m.loss_fn(p, b)[0]).lower(param_sds, batch)
    ca = lowered.compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


@pytest.mark.parametrize("arch,B,S", [
    ("paper-charlm", 8, 64),
    ("granite-3-2b", 2, 128),
])
def test_analytic_matches_cost_analysis(arch, B, S):
    cfg = get_config(arch)
    if arch != "paper-charlm":
        cfg = cfg.replace(n_layers=2, dtype="float32")
    got = measured_fwd_flops(cfg, B, S)
    want = cm.fwd_flops(cfg, B * S, (S + 1) / 2)
    ratio = got / want
    # the analytic model tracks matmuls exactly; elementwise/norm/softmax
    # bookkeeping differences stay within ~20%
    assert 0.8 < ratio < 1.25, (got, want, ratio)


def test_param_bytes_matches_real_params():
    from repro.models import build_model, param_count
    cfg = get_config("paper-charlm")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    got = cm.model_param_bytes(cfg)
    want = param_count(params) * 4  # float32
    assert abs(got - want) / want < 0.02, (got, want)


def test_roofline_terms_structure():
    t = cm.roofline_terms("kimi-k2-1t-a32b", "train_4k", 256, 1e12)
    assert set(t) >= {"compute_s", "memory_s", "collective_s", "dominant",
                      "useful_ratio", "model_flops"}
    assert t["dominant"] in ("compute", "memory", "collective")
    # kimi active fraction: ~32B of 1T
    assert t["active_param_bytes"] < 0.1 * t["param_bytes"]