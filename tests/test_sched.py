"""Scheduler adapter units: artifact rendering + simulated lifecycles."""
import json

from repro.sched import (HybridAdapter, JobSpec, JobState, K8sAdapter,
                         SlurmAdapter, pod_manifest)


def mkspec(name="fl-client-0", site="hpc", **kw):
    return JobSpec(name=name, command="python -m repro.launch.train",
                   site=site, **kw)


def test_sbatch_artifact_contents():
    s = SlurmAdapter()
    h = s.submit(mkspec(gpus_per_node=2, nodes=3, mem_gb=64))
    art = h.artifact
    assert "#SBATCH --nodes=3" in art
    assert "#SBATCH --gres=gpu:2" in art
    assert "#SBATCH --mem=64G" in art
    assert "srun python -m repro.launch.train" in art


def test_slurm_capacity_queueing():
    s = SlurmAdapter(total_nodes=2)
    h1 = s.submit(mkspec("a", nodes=2))
    h2 = s.submit(mkspec("b", nodes=1))
    s.set_workload(h1.job_id, 100)
    s.set_workload(h2.job_id, 10)
    s.advance(1)
    assert s.poll(h1.job_id) == JobState.RUNNING
    assert s.poll(h2.job_id) == JobState.PENDING      # no room
    s.advance(100)
    assert s.poll(h1.job_id) == JobState.COMPLETED
    s.advance(1)
    assert s.poll(h2.job_id) == JobState.RUNNING


def test_pod_manifest_valid():
    spec = mkspec(site="cloud", gpus_per_node=1, preemptible=True)
    man = pod_manifest(spec)
    assert man["kind"] == "Pod"
    res = man["spec"]["containers"][0]["resources"]["limits"]
    assert res["nvidia.com/gpu"] == "1"
    assert "tolerations" in man["spec"]
    json.dumps(man)                                    # serialisable


def test_k8s_autoscaling():
    k = K8sAdapter(initial_nodes=1, max_nodes=4, scale_step=1)
    hs = [k.submit(mkspec(f"p{i}", site="cloud")) for i in range(4)]
    for h in hs:
        k.set_workload(h.job_id, 1000)
    k.advance(1)
    k.advance(1)
    running = sum(k.poll(h.job_id) == JobState.RUNNING for h in hs)
    assert running >= 2                                # scaled beyond 1
    assert k.nodes > 1


def test_k8s_spot_preemption():
    k = K8sAdapter(initial_nodes=10, preempt_prob_per_min=60.0, seed=0)
    h = k.submit(mkspec("spot", site="cloud", preemptible=True))
    k.set_workload(h.job_id, 1e6)
    for _ in range(20):
        k.advance(10)
    assert k.poll(h.job_id) == JobState.PREEMPTED


def test_hybrid_routing_and_overflow():
    hy = HybridAdapter(slurm=SlurmAdapter(total_nodes=1), k8s=K8sAdapter())
    h_hpc = hy.submit(mkspec("a", site="hpc"))
    assert h_hpc.job_id.startswith("slurm-")
    h_cloud = hy.submit(mkspec("b", site="cloud"))
    assert h_cloud.job_id.startswith("pod-")
    # saturate slurm -> overflow to cloud
    hy.advance(0.1)
    h_burst = hy.submit(mkspec("c", site="hpc"))
    assert h_burst.job_id.startswith("pod-")


def test_hybrid_elastic_overflow_drains_and_routes_back():
    """Saturate the SLURM pool -> HPC jobs burst to K8s; once the pool
    drains, new HPC jobs route back to SLURM."""
    hy = HybridAdapter(slurm=SlurmAdapter(total_nodes=2),
                       k8s=K8sAdapter(initial_nodes=8, max_nodes=8))
    filling = [hy.submit(mkspec(f"f{i}", site="hpc"), work_s=50.0)
               for i in range(2)]
    hy.advance(0.0)
    assert all(h.job_id.startswith("slurm-") for h in filling)
    assert all(hy.poll(h.job_id) == JobState.RUNNING for h in filling)
    # pool full (queued work counts too): the burst lands on K8s
    burst = [hy.submit(mkspec(f"b{i}", site="hpc"), work_s=10.0)
             for i in range(3)]
    assert all(h.job_id.startswith("pod-") for h in burst)
    assert all(hy.site_of(h.job_id) == "cloud" for h in burst)
    hy.advance(0.0)                   # settle: burst pods start immediately
    # drain everything, then route back home
    hy.advance(60.0)
    assert all(hy.poll(h.job_id) == JobState.COMPLETED
               for h in filling + burst)
    back = hy.submit(mkspec("back", site="hpc"), work_s=1.0)
    assert back.job_id.startswith("slurm-")
    assert hy.site_of(back.job_id) == "hpc"


def test_slurm_workload_attached_to_handle():
    """Regression for the `_find_id` identity lookup: a COPIED/reused spec
    must not silently fall back to the 60 s default workload."""
    import dataclasses

    s = SlurmAdapter(total_nodes=4)
    spec = mkspec("orig")
    h1 = s.submit(spec, work_s=5.0)
    h2 = s.submit(dataclasses.replace(spec, name="copy"), work_s=7.0)
    h3 = s.submit(spec)                      # reused spec object, no work
    s.set_workload(h3.job_id, 9.0)
    assert (h1.work_s, h2.work_s, h3.work_s) == (5.0, 7.0, 9.0)
    s.advance(0.0)                           # settle: all three start at t=0
    s.advance(6.0)
    assert s.poll(h1.job_id) == JobState.COMPLETED
    assert s.poll(h2.job_id) == JobState.RUNNING
    s.advance(4.0)
    assert s.poll(h2.job_id) == JobState.COMPLETED
    assert s.poll(h3.job_id) == JobState.COMPLETED
    assert h1.end_time == 5.0 and h2.end_time == 7.0 and h3.end_time == 9.0


def test_public_capacity_api():
    s = SlurmAdapter(total_nodes=3)
    assert s.total_capacity() == 3 and s.nodes_in_use() == 0
    h = s.submit(mkspec("a", nodes=2), work_s=100.0)
    q = s.submit(mkspec("b", nodes=2), work_s=100.0)
    s.advance(0.0)
    assert s.nodes_in_use() == 2               # only "a" fits
    assert s.committed_nodes() == 4            # queued work counts
    k = K8sAdapter(initial_nodes=2, max_nodes=4)
    assert k.total_capacity() == 4
    k.submit(mkspec("p", site="cloud"), work_s=100.0)
    k.advance(0.0)
    assert k.nodes_in_use() == 1


def test_coarse_advance_starts_queued_jobs_at_exact_times():
    """Regression: advance_to must step through intermediate transitions —
    a queued job starts the instant capacity frees, not at the (coarse)
    destination time.  This is what keeps the real pool identical to the
    SchedulerBackend's lookahead clone under contention."""
    s = SlurmAdapter(total_nodes=1)
    a = s.submit(mkspec("a"), work_s=10.0)
    b = s.submit(mkspec("b"), work_s=5.0)
    s.advance(0.0)
    s.advance(25.0)                       # one coarse jump past both jobs
    assert a.end_time == 10.0
    assert b.start_time == 10.0           # NOT 25.0
    assert b.end_time == 15.0


def test_adapter_state_roundtrip():
    """state_dict/load_state reproduces mid-flight pools exactly — the
    property the SchedulerBackend's checkpointing builds on."""
    hy = HybridAdapter(slurm=SlurmAdapter(total_nodes=1, queue_noise=0.3),
                       k8s=K8sAdapter(initial_nodes=1, max_nodes=4,
                                      preempt_prob_per_min=5.0))
    for i in range(3):
        hy.submit(mkspec(f"h{i}", site="hpc"), work_s=20.0 + i)
        hy.submit(mkspec(f"c{i}", site="cloud", preemptible=True),
                  work_s=15.0 + i)
    hy.advance(5.0)
    twin = HybridAdapter(slurm=SlurmAdapter(total_nodes=1, queue_noise=0.3),
                         k8s=K8sAdapter(initial_nodes=1, max_nodes=4,
                                        preempt_prob_per_min=5.0))
    twin.load_state(hy.state_dict())
    # both futures play out identically
    hy.advance(100.0)
    twin.advance(100.0)
    a = {jid: (h.state.value, h.start_time, h.end_time)
         for jid, h in {**hy.slurm.jobs, **hy.k8s.jobs}.items()}
    b = {jid: (h.state.value, h.start_time, h.end_time)
         for jid, h in {**twin.slurm.jobs, **twin.k8s.jobs}.items()}
    assert a == b
