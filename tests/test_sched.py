"""Scheduler adapter units: artifact rendering + simulated lifecycles."""
import json

from repro.sched import (HybridAdapter, JobSpec, JobState, K8sAdapter,
                         SlurmAdapter, pod_manifest)


def mkspec(name="fl-client-0", site="hpc", **kw):
    return JobSpec(name=name, command="python -m repro.launch.train",
                   site=site, **kw)


def test_sbatch_artifact_contents():
    s = SlurmAdapter()
    h = s.submit(mkspec(gpus_per_node=2, nodes=3, mem_gb=64))
    art = h.artifact
    assert "#SBATCH --nodes=3" in art
    assert "#SBATCH --gres=gpu:2" in art
    assert "#SBATCH --mem=64G" in art
    assert "srun python -m repro.launch.train" in art


def test_slurm_capacity_queueing():
    s = SlurmAdapter(total_nodes=2)
    h1 = s.submit(mkspec("a", nodes=2))
    h2 = s.submit(mkspec("b", nodes=1))
    s.set_workload(h1.job_id, 100)
    s.set_workload(h2.job_id, 10)
    s.advance(1)
    assert s.poll(h1.job_id) == JobState.RUNNING
    assert s.poll(h2.job_id) == JobState.PENDING      # no room
    s.advance(100)
    assert s.poll(h1.job_id) == JobState.COMPLETED
    s.advance(1)
    assert s.poll(h2.job_id) == JobState.RUNNING


def test_pod_manifest_valid():
    spec = mkspec(site="cloud", gpus_per_node=1, preemptible=True)
    man = pod_manifest(spec)
    assert man["kind"] == "Pod"
    res = man["spec"]["containers"][0]["resources"]["limits"]
    assert res["nvidia.com/gpu"] == "1"
    assert "tolerations" in man["spec"]
    json.dumps(man)                                    # serialisable


def test_k8s_autoscaling():
    k = K8sAdapter(initial_nodes=1, max_nodes=4, scale_step=1)
    hs = [k.submit(mkspec(f"p{i}", site="cloud")) for i in range(4)]
    for h in hs:
        k.set_workload(h.job_id, 1000)
    k.advance(1)
    k.advance(1)
    running = sum(k.poll(h.job_id) == JobState.RUNNING for h in hs)
    assert running >= 2                                # scaled beyond 1
    assert k.nodes > 1


def test_k8s_spot_preemption():
    k = K8sAdapter(initial_nodes=10, preempt_prob_per_min=60.0, seed=0)
    h = k.submit(mkspec("spot", site="cloud", preemptible=True))
    k.set_workload(h.job_id, 1e6)
    for _ in range(20):
        k.advance(10)
    assert k.poll(h.job_id) == JobState.PREEMPTED


def test_hybrid_routing_and_overflow():
    hy = HybridAdapter(slurm=SlurmAdapter(total_nodes=1), k8s=K8sAdapter())
    h_hpc = hy.submit(mkspec("a", site="hpc"))
    assert h_hpc.job_id.startswith("slurm-")
    h_cloud = hy.submit(mkspec("b", site="cloud"))
    assert h_cloud.job_id.startswith("pod-")
    # saturate slurm -> overflow to cloud
    hy.advance(0.1)
    h_burst = hy.submit(mkspec("c", site="hpc"))
    assert h_burst.job_id.startswith("pod-")
