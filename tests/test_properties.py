"""Hypothesis property-based tests on system invariants: aggregation,
compression, and non-IID partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

from repro.core import aggregation as agg
from repro.core.compression import (CompressionConfig, payload_bytes,
                                    quantize_dequant, topk_sparsify)
from repro.data.partition import (partition_by_class, partition_dirichlet,
                                  partition_quantity_skew)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")

floats = st.floats(-10, 10, allow_nan=False, width=32)


# ---------------------------------------------------------------- aggregation
@given(hnp.arrays(np.float32, st.tuples(st.integers(2, 6), st.integers(1, 16)),
                  elements=floats))
def test_weighted_mean_of_identical_is_identity(d):
    d = np.repeat(d[:1], d.shape[0], axis=0)          # all clients identical
    out = agg.weighted_mean({"x": jnp.asarray(d)},
                            jnp.ones(d.shape[0]))["x"]
    np.testing.assert_allclose(out, d[0], rtol=1e-5, atol=1e-5)


@given(hnp.arrays(np.float32, st.tuples(st.integers(2, 6), st.integers(1, 16)),
                  elements=floats))
def test_weighted_mean_within_convex_hull(d):
    w = jnp.ones(d.shape[0])
    out = np.asarray(agg.weighted_mean({"x": jnp.asarray(d)}, w)["x"])
    assert (out <= d.max(0) + 1e-4).all()
    assert (out >= d.min(0) - 1e-4).all()


@given(hnp.arrays(np.float32, st.tuples(st.integers(3, 6), st.integers(1, 8)),
                  elements=floats),
       st.integers(0, 5))
def test_masked_client_never_contributes(d, drop):
    C = d.shape[0]
    drop = drop % C
    mask = np.ones(C, np.float32)
    mask[drop] = 0
    w = agg.effective_weights(jnp.ones(C), jnp.asarray(mask))
    out1 = np.asarray(agg.weighted_mean({"x": jnp.asarray(d)}, w)["x"])
    d2 = d.copy()
    d2[drop] = 1e6                                     # poison the masked client
    out2 = np.asarray(agg.weighted_mean({"x": jnp.asarray(d2)}, w)["x"])
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_effective_weights_loss_mode_prefers_low_loss():
    w = agg.effective_weights(jnp.ones(2), jnp.ones(2),
                              jnp.asarray([0.1, 10.0]), "weighted")
    assert float(w[0]) > float(w[1])


# ---------------------------------------------------------------- compression
@given(hnp.arrays(np.float32, st.integers(1, 600), elements=floats))
def test_quantize_error_bounded_by_half_step(x):
    x = jnp.asarray(x)
    y = quantize_dequant(x, bits=8, block=128, stochastic=False)
    xb = np.asarray(x)
    # global bound: per-block scale <= global max / 127
    step = np.abs(xb).max() / 127 if xb.size else 0
    assert (np.abs(np.asarray(y) - xb) <= step * 0.500001 + 1e-6).all()


@given(hnp.arrays(np.float32, st.integers(1, 600), elements=floats),
       st.integers(1, 64))
def test_topk_is_subset_with_unchanged_values(x, k):
    x = jnp.asarray(x)
    y = np.asarray(topk_sparsify(x, k / 128, block=128))
    xv = np.asarray(x)
    nz = y != 0
    np.testing.assert_array_equal(y[nz], xv[nz])
    # zeros only where magnitude below the per-block max
    assert (np.abs(y) <= np.abs(xv) + 1e-9).all()


@given(st.integers(1, 2000), st.sampled_from([4, 8]),
       st.floats(0.01, 0.9))
def test_payload_bytes_monotone(n, bits, frac):
    tree = {"w": np.zeros(n, np.float32)}
    full = payload_bytes(tree, None)
    q = payload_bytes(tree, CompressionConfig(quantize_bits=bits))
    assert full == n * 4
    assert q < full + 132  # quant never bigger (mod per-block scale overhead)
    both = payload_bytes(tree, CompressionConfig(quantize_bits=bits,
                                                 topk_frac=frac))
    lighter = payload_bytes(tree, CompressionConfig(quantize_bits=bits,
                                                    topk_frac=frac / 2 + 1e-3))
    assert lighter <= both + 1


def test_paper_table4_compression_ratio():
    """Paper Table 4: 43-45 MB -> 13-16 MB (~65% reduction) with
    quantization+sparsification.  Our defaults should land in that band."""
    tree = {"w": np.zeros(11_250_000, np.float32)}     # ~45 MB fp32 model
    full = payload_bytes(tree, None)
    comp = payload_bytes(tree, CompressionConfig(quantize_bits=8,
                                                 topk_frac=0.1))
    ratio = comp / full
    assert 0.1 < ratio < 0.45, ratio


# ---------------------------------------------------------------- partitioning
@given(st.integers(40, 400), st.integers(2, 10))
def test_partition_by_class_covers_all(n, c):
    y = np.random.default_rng(0).integers(0, 10, n)
    parts = partition_by_class(y, c, 2)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n                # disjoint cover


@given(st.integers(100, 500), st.integers(2, 8),
       st.floats(0.05, 5.0))
def test_dirichlet_partition_covers_all(n, c, alpha):
    y = np.random.default_rng(1).integers(0, 10, n)
    parts = partition_dirichlet(y, c, alpha, min_size=1)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


def test_pathological_partition_is_skewed():
    y = np.random.default_rng(2).integers(0, 10, 2000)
    parts = partition_by_class(y, 10, 2)
    n_classes = [len(np.unique(y[p])) for p in parts]
    # 2 shards per client; a shard can straddle one class boundary, so 2-4
    # classes max, and on average the paper's 2-3.
    assert max(n_classes) <= 4
    assert np.mean(n_classes) <= 3.0


@given(st.integers(50, 500), st.integers(2, 8))
def test_quantity_skew_covers_all(n, c):
    parts = partition_quantity_skew(n, c)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx) == n


# ------------------------------------------------------------ async resume
# checkpoint -> restore at a random event index is a NO-OP on the final
# state, for random (K, T, dropout, preempt, recovery_policy) configs
_ASYNC_CACHE: dict = {}


def _mini_async(K, T, dropout, preempt, policy, mgr=None):
    from repro.core import AsyncConfig, FLConfig
    from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
    from repro.models.cnn import CNN, CNNConfig
    from repro.orchestrator import (AsyncOrchestrator, FaultConfig,
                                    StragglerPolicy, make_hybrid_fleet)
    seed, n_clients = 5, 4
    if "base" not in _ASYNC_CACHE:
        data = medmnist_like(n=200, seed=seed)
        parts = partition_dirichlet(data.y, n_clients, alpha=0.5, seed=seed)
        model = CNN(CNNConfig("prop-cnn", (28, 28, 1), 9, channels=(2, 4),
                              dense=8))
        _ASYNC_CACHE["base"] = (data, parts, model,
                                model.init(jax.random.PRNGKey(seed)))
    data, parts, model, params = _ASYNC_CACHE["base"]
    orch = AsyncOrchestrator(
        fleet=make_hybrid_fleet(2, 2, seed=seed,
                                data_sizes=[len(p) for p in parts]),
        fed_data=FederatedDataset(data, parts, seed=seed),
        loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=n_clients, local_steps=1,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=K, commit_timeout_s=T,
                              max_concurrency=3, max_staleness=50),
        straggler=StragglerPolicy(contention_sigma=0.5),
        faults=FaultConfig(dropout_prob=dropout, spot_preempt_prob=preempt,
                           recovery_policy=policy),
        batch_size=4, flops_per_client_round=2e12,
        checkpoint_mgr=mgr, seed=seed)
    # the jit'd steps depend only on (model cfg, FLConfig, K) — share them
    # across examples so each K compiles once
    if K in _ASYNC_CACHE:
        orch._client_update, orch._commit_step = _ASYNC_CACHE[K]
    else:
        _ASYNC_CACHE[K] = (orch._client_update, orch._commit_step)
    return orch, params


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 3), st.sampled_from([0.0, 0.6]),
       st.sampled_from([0.0, 0.3]), st.sampled_from([0.0, 0.5]),
       st.sampled_from(["restart", "resume", "discard"]),
       st.integers(0, 30))
def test_async_checkpoint_restore_is_noop(K, T, dropout, preempt, policy,
                                          kill_idx):
    import tempfile
    from repro.checkpoint import AsyncCheckpointManager

    n_commits = 3
    straight, params = _mini_async(K, T, dropout, preempt, policy)
    p_straight, _ = straight.run(params, n_commits)
    events = straight.events_processed
    assert events, "run produced no events"
    # cut at the (kill_idx mod len)-th processed event's sim-time
    budget = events[kill_idx % len(events)][0]

    with tempfile.TemporaryDirectory() as ckdir:
        mgr = AsyncCheckpointManager(ckdir, keep=2)
        killed, params2 = _mini_async(K, T, dropout, preempt, policy, mgr=mgr)
        killed.run(params2, n_commits, max_sim_time=budget)

        resumed, params3 = _mini_async(K, T, dropout, preempt, policy)
        p0, st0 = mgr.restore_async(resumed, params3)
        p_resumed, _ = resumed.run(p0, n_commits, server_state=st0)

    assert resumed.version == straight.version
    assert [l.sim_time for l in resumed.logs] \
        == [l.sim_time for l in straight.logs]
    assert resumed.events_processed == events
    for a, b in zip(jax.tree.leaves(p_resumed), jax.tree.leaves(p_straight)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


# ------------------------------------------------------- pipeline invariants
# hypothesis front-end over the checkers in test_pipeline_properties.py
# (which also runs them as a seeded sweep when hypothesis is unavailable):
# slot-permutation invariance, mask cancellation for arbitrary
# participation vectors, and chunked == single-shot commit accumulation
from test_pipeline_properties import (check_chunked_equals_single_shot,  # noqa: E402
                                      check_masked_equals_plain,
                                      check_permutation_invariant)


@st.composite
def _buffers(draw):
    K = draw(st.integers(2, 8))
    D = draw(st.integers(1, 12))
    d = draw(hnp.arrays(np.float32, (K, D), elements=floats))
    w = draw(hnp.arrays(np.float32, (K,),
                        elements=st.floats(0.1, 5, width=32)))
    m = np.asarray(draw(st.lists(st.integers(0, 1), min_size=K, max_size=K)),
                   np.float32)
    s = np.asarray(draw(st.lists(st.integers(0, 10), min_size=K, max_size=K)),
                   np.float32)
    l = draw(hnp.arrays(np.float32, (K,),
                        elements=st.floats(0.0, 5.0, width=32)))
    return d, w, m, s, l


@settings(max_examples=15, deadline=None)
@given(_buffers(), st.integers(0, 10_000), st.booleans())
def test_commit_is_permutation_invariant_within_buffer(buf, pseed, secure):
    check_permutation_invariant(buf, perm_seed=pseed, secure=secure)


@settings(max_examples=15, deadline=None)
@given(_buffers())
def test_masked_equals_plain_for_arbitrary_participation(buf):
    check_masked_equals_plain(buf)


@settings(max_examples=10, deadline=None)
@given(_buffers(), st.integers(1, 8), st.booleans())
def test_chunked_commit_equals_single_shot(buf, C, secure):
    check_chunked_equals_single_shot(buf, C, secure)
