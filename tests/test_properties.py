"""Hypothesis property-based tests on system invariants: aggregation,
compression, and non-IID partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

from repro.core import aggregation as agg
from repro.core.compression import (CompressionConfig, payload_bytes,
                                    quantize_dequant, topk_sparsify)
from repro.data.partition import (partition_by_class, partition_dirichlet,
                                  partition_quantity_skew)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")

floats = st.floats(-10, 10, allow_nan=False, width=32)


# ---------------------------------------------------------------- aggregation
@given(hnp.arrays(np.float32, st.tuples(st.integers(2, 6), st.integers(1, 16)),
                  elements=floats))
def test_weighted_mean_of_identical_is_identity(d):
    d = np.repeat(d[:1], d.shape[0], axis=0)          # all clients identical
    out = agg.weighted_mean({"x": jnp.asarray(d)},
                            jnp.ones(d.shape[0]))["x"]
    np.testing.assert_allclose(out, d[0], rtol=1e-5, atol=1e-5)


@given(hnp.arrays(np.float32, st.tuples(st.integers(2, 6), st.integers(1, 16)),
                  elements=floats))
def test_weighted_mean_within_convex_hull(d):
    w = jnp.ones(d.shape[0])
    out = np.asarray(agg.weighted_mean({"x": jnp.asarray(d)}, w)["x"])
    assert (out <= d.max(0) + 1e-4).all()
    assert (out >= d.min(0) - 1e-4).all()


@given(hnp.arrays(np.float32, st.tuples(st.integers(3, 6), st.integers(1, 8)),
                  elements=floats),
       st.integers(0, 5))
def test_masked_client_never_contributes(d, drop):
    C = d.shape[0]
    drop = drop % C
    mask = np.ones(C, np.float32)
    mask[drop] = 0
    w = agg.effective_weights(jnp.ones(C), jnp.asarray(mask))
    out1 = np.asarray(agg.weighted_mean({"x": jnp.asarray(d)}, w)["x"])
    d2 = d.copy()
    d2[drop] = 1e6                                     # poison the masked client
    out2 = np.asarray(agg.weighted_mean({"x": jnp.asarray(d2)}, w)["x"])
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_effective_weights_loss_mode_prefers_low_loss():
    w = agg.effective_weights(jnp.ones(2), jnp.ones(2),
                              jnp.asarray([0.1, 10.0]), "weighted")
    assert float(w[0]) > float(w[1])


# ---------------------------------------------------------------- compression
@given(hnp.arrays(np.float32, st.integers(1, 600), elements=floats))
def test_quantize_error_bounded_by_half_step(x):
    x = jnp.asarray(x)
    y = quantize_dequant(x, bits=8, block=128, stochastic=False)
    xb = np.asarray(x)
    # global bound: per-block scale <= global max / 127
    step = np.abs(xb).max() / 127 if xb.size else 0
    assert (np.abs(np.asarray(y) - xb) <= step * 0.500001 + 1e-6).all()


@given(hnp.arrays(np.float32, st.integers(1, 600), elements=floats),
       st.integers(1, 64))
def test_topk_is_subset_with_unchanged_values(x, k):
    x = jnp.asarray(x)
    y = np.asarray(topk_sparsify(x, k / 128, block=128))
    xv = np.asarray(x)
    nz = y != 0
    np.testing.assert_array_equal(y[nz], xv[nz])
    # zeros only where magnitude below the per-block max
    assert (np.abs(y) <= np.abs(xv) + 1e-9).all()


@given(st.integers(1, 2000), st.sampled_from([4, 8]),
       st.floats(0.01, 0.9))
def test_payload_bytes_monotone(n, bits, frac):
    tree = {"w": np.zeros(n, np.float32)}
    full = payload_bytes(tree, None)
    q = payload_bytes(tree, CompressionConfig(quantize_bits=bits))
    assert full == n * 4
    assert q < full + 132  # quant never bigger (mod per-block scale overhead)
    both = payload_bytes(tree, CompressionConfig(quantize_bits=bits,
                                                 topk_frac=frac))
    lighter = payload_bytes(tree, CompressionConfig(quantize_bits=bits,
                                                    topk_frac=frac / 2 + 1e-3))
    assert lighter <= both + 1


def test_paper_table4_compression_ratio():
    """Paper Table 4: 43-45 MB -> 13-16 MB (~65% reduction) with
    quantization+sparsification.  Our defaults should land in that band."""
    tree = {"w": np.zeros(11_250_000, np.float32)}     # ~45 MB fp32 model
    full = payload_bytes(tree, None)
    comp = payload_bytes(tree, CompressionConfig(quantize_bits=8,
                                                 topk_frac=0.1))
    ratio = comp / full
    assert 0.1 < ratio < 0.45, ratio


# ---------------------------------------------------------------- partitioning
@given(st.integers(40, 400), st.integers(2, 10))
def test_partition_by_class_covers_all(n, c):
    y = np.random.default_rng(0).integers(0, 10, n)
    parts = partition_by_class(y, c, 2)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n                # disjoint cover


@given(st.integers(100, 500), st.integers(2, 8),
       st.floats(0.05, 5.0))
def test_dirichlet_partition_covers_all(n, c, alpha):
    y = np.random.default_rng(1).integers(0, 10, n)
    parts = partition_dirichlet(y, c, alpha, min_size=1)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


def test_pathological_partition_is_skewed():
    y = np.random.default_rng(2).integers(0, 10, 2000)
    parts = partition_by_class(y, 10, 2)
    n_classes = [len(np.unique(y[p])) for p in parts]
    # 2 shards per client; a shard can straddle one class boundary, so 2-4
    # classes max, and on average the paper's 2-3.
    assert max(n_classes) <= 4
    assert np.mean(n_classes) <= 3.0


@given(st.integers(50, 500), st.integers(2, 8))
def test_quantity_skew_covers_all(n, c):
    parts = partition_quantity_skew(n, c)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx) == n
