"""Update-pipeline algebra the batched/chunked commit paths rely on,
checked over seeded random buffers (no optional deps — this is the
always-on counterpart of the hypothesis fuzzers in test_properties.py,
which import these checkers and explore the same invariants with
generated inputs when hypothesis is installed):

  * slot-permutation invariance — the commit buffer is a set;
  * secure-agg mask cancellation for ARBITRARY participation vectors;
  * chunked accumulation (AsyncConfig.commit_chunk) == single-shot.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_round import (AsyncConfig, build_buffer_commit_step,
                                    build_chunked_commit_steps)
from repro.core.pipeline import build_update_pipeline
from repro.core.round import FLConfig
from repro.optim import get_server_optimizer

_PIPES = {}


def pipe(secure: bool):
    if secure not in _PIPES:
        _PIPES[secure] = build_update_pipeline(
            FLConfig(mode="async", secure_agg=secure))
    return _PIPES[secure]


def random_buffer(seed: int, K=None):
    """One random commit buffer: deltas [K, D], weights, 0/1 participation
    mask, integer staleness, losses."""
    rng = np.random.default_rng(seed)
    K = K or int(rng.integers(2, 9))
    D = int(rng.integers(1, 13))
    return (rng.normal(0, 3, (K, D)).astype(np.float32),
            rng.uniform(0.1, 5, K).astype(np.float32),
            rng.integers(0, 2, K).astype(np.float32),
            rng.integers(0, 11, K).astype(np.float32),
            rng.uniform(0, 5, K).astype(np.float32))


def combine(p, d, w, m, s, l, ids=None):
    delta, _, _ = p.combine(
        {"x": jnp.asarray(d)}, jnp.asarray(w), jnp.asarray(m),
        jnp.asarray(l), jax.random.PRNGKey(42),
        ids=None if ids is None else jnp.asarray(ids, jnp.int32),
        staleness=jnp.asarray(s), exponent=jnp.float32(0.5))
    return np.asarray(delta["x"])


# ------------------------------------------------------- property checkers
def check_permutation_invariant(buf, perm_seed: int, secure: bool):
    """Reordering slots (ids travelling with their slots, so each keeps its
    mask identity) changes only float summation order."""
    d, w, m, s, l = buf
    K = d.shape[0]
    perm = np.random.default_rng(perm_seed).permutation(K)
    ids = np.arange(K)
    base = combine(pipe(secure), d, w, m, s, l, ids=ids)
    shuf = combine(pipe(secure), d[perm], w[perm], m[perm], s[perm],
                   l[perm], ids=ids[perm])
    np.testing.assert_allclose(shuf, base, rtol=1e-4, atol=1e-5)


def check_masked_equals_plain(buf):
    """Pairwise masks cancel for EVERY participation vector, so the
    server's masked view equals the plain aggregate to f32 cancellation."""
    d, w, m, s, l = buf
    plain = combine(pipe(False), d, w, m, s, l)
    masked = combine(pipe(True), d, w, m, s, l, ids=np.arange(d.shape[0]))
    np.testing.assert_allclose(masked, plain, rtol=1e-4, atol=1e-5)


def check_chunked_equals_single_shot(buf, C: int, secure: bool):
    """Accumulating the buffer in C-sized chunks (fresh fold_in rng and
    arange ids per chunk, zero-padded tail — exactly what
    AsyncOrchestrator._commit_chunked does) and normalising once equals the
    single-shot commit to ~1e-5."""
    d, w, m, s, l = buf
    K, D = d.shape
    cfg = FLConfig(mode="async", secure_agg=secure)
    opt = get_server_optimizer("fedavg")
    params = {"x": jnp.zeros(D, jnp.float32)}
    state = opt.init(params)
    r = jax.random.PRNGKey(7)

    commit = build_buffer_commit_step(opt, cfg, AsyncConfig(buffer_size=K))
    p1, _, _ = commit(params, state, {"x": jnp.asarray(d)}, jnp.asarray(w),
                      jnp.asarray(s), jnp.asarray(l), jnp.asarray(m),
                      jnp.arange(K, dtype=jnp.int32), jnp.float32(0.5), r)

    acc_step, fin_step = build_chunked_commit_steps(
        opt, cfg, AsyncConfig(buffer_size=K, commit_chunk=C))
    acc = {"x": jnp.zeros(D, jnp.float32)}
    wsum = jnp.float32(0.0)
    ids = jnp.arange(C, dtype=jnp.int32)
    for k, lo in enumerate(range(0, K, C)):
        n = min(C, K - lo)
        pad = C - n

        def pad0(v):
            return jnp.asarray(np.concatenate(
                [v[lo:lo + n], np.zeros(pad, np.float32)]))

        dk = np.concatenate([d[lo:lo + n], np.zeros((pad, D), np.float32)])
        acc, wsum = acc_step(acc, wsum, {"x": jnp.asarray(dk)}, pad0(w),
                             pad0(s), pad0(l), pad0(m), ids,
                             jnp.float32(0.5), jax.random.fold_in(r, k))
    p2, _, _ = fin_step(params, state, acc, wsum)
    np.testing.assert_allclose(np.asarray(p2["x"]), np.asarray(p1["x"]),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------ seeded-sweep tests
@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("secure", [False, True])
def test_commit_is_permutation_invariant_within_buffer(seed, secure):
    check_permutation_invariant(random_buffer(seed), perm_seed=seed + 100,
                                secure=secure)


@pytest.mark.parametrize("seed", range(10))
def test_masked_equals_plain_for_arbitrary_participation(seed):
    check_masked_equals_plain(random_buffer(seed))


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("secure", [False, True])
def test_chunked_commit_equals_single_shot(seed, secure):
    buf = random_buffer(seed, K=7)
    C = [1, 2, 3, 4, 5, 7][seed]           # covers C=1, uneven tails, C=K
    check_chunked_equals_single_shot(buf, C, secure)


def test_all_masked_out_buffer_is_safe():
    """participation == all zeros (a fully dead timeout commit) must not
    divide by zero or leak uncancelled masks."""
    d, w, m, s, l = random_buffer(3)
    m[:] = 0.0
    plain = combine(pipe(False), d, w, m, s, l)
    masked = combine(pipe(True), d, w, m, s, l, ids=np.arange(d.shape[0]))
    assert np.isfinite(plain).all() and np.isfinite(masked).all()
    np.testing.assert_allclose(masked, plain, rtol=1e-4, atol=1e-5)
