"""FL round-step semantics: exec-mode equivalence, masking, FedProx,
server optimizers, hierarchical compression path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CompressionConfig, FLConfig, build_fl_round_step
from repro.models import build_model
from repro.optim import get_client_optimizer, get_server_optimizer

C, H, b, S = 4, 2, 2, 16


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-charlm").replace(n_layers=2, d_model=64, d_ff=128,
                                             n_heads=2, kv_heads=2)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (C, H, b, S + 1), 0,
                              cfg.vocab, jnp.int32)
    batches = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
    return m, params, batches


def run(setup, **kw):
    m, params, batches = setup
    defaults = dict(num_clients=C, local_steps=H, client_lr=0.1)
    defaults.update(kw)
    fl = FLConfig(**defaults)
    step = jax.jit(build_fl_round_step(
        m.loss_fn, get_client_optimizer("sgd"),
        get_server_optimizer("fedavg"), fl,
        n_pods=kw.pop("n_pods", 1) if "n_pods" in kw else 1))
    weights = jnp.ones((C,))
    mask = jnp.ones((C,))
    return step(params, (), batches, weights, mask, jax.random.PRNGKey(2))


def test_parallel_equals_sequential(setup):
    p1, _, m1 = run(setup, client_exec="parallel")
    p2, _, m2 = run(setup, client_exec="sequential")
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m1["client_loss"], m2["client_loss"], rtol=1e-5)


def test_masked_client_is_ignored(setup):
    m, params, batches = setup
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1)
    step = jax.jit(build_fl_round_step(
        m.loss_fn, get_client_optimizer("sgd"), get_server_optimizer("fedavg"), fl))
    weights = jnp.ones((C,))
    mask = jnp.array([1.0, 1.0, 1.0, 0.0])
    p1, _, _ = step(params, (), batches, weights, mask, jax.random.PRNGKey(2))
    # corrupt client 3's data; result must be identical
    bad = jax.tree.map(lambda x: x.at[3].set(x[3] * 0 + 1), batches)
    p2, _, _ = step(params, (), bad, weights, mask, jax.random.PRNGKey(2))
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b_, rtol=1e-6, atol=1e-7)


def test_fedprox_shrinks_delta(setup):
    _, _, m0 = run(setup, fedprox_mu=0.0)
    _, _, m1 = run(setup, fedprox_mu=1.0)
    assert float(m1["delta_norm"]) < float(m0["delta_norm"])


def test_single_client_fullmask_equals_local_sgd(setup):
    m, params, batches = setup
    fl = FLConfig(num_clients=1, local_steps=H, client_lr=0.1)
    step = jax.jit(build_fl_round_step(
        m.loss_fn, get_client_optimizer("sgd"), get_server_optimizer("fedavg"), fl))
    one = jax.tree.map(lambda x: x[:1], batches)
    p1, _, _ = step(params, (), one, jnp.ones((1,)), jnp.ones((1,)),
                    jax.random.PRNGKey(2))
    # manual 2-step SGD
    w = params
    for h in range(H):
        g = jax.grad(lambda p: m.loss_fn(p, jax.tree.map(
            lambda x: x[0, h], one))[0])(w)
        w = jax.tree.map(lambda p, gi: p - 0.1 * gi, w, g)
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(w)):
        np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-6)


def test_compression_changes_but_approximates(setup):
    p_ref, _, _ = run(setup)
    p_q, _, _ = run(setup, compression=CompressionConfig(
        quantize_bits=8, stochastic_rounding=False))
    ref_l = jax.tree.leaves(p_ref)
    q_l = jax.tree.leaves(p_q)
    diffs = [float(jnp.abs(a - b_).max()) for a, b_ in zip(ref_l, q_l)]
    assert max(diffs) > 0                     # actually compressed
    rel = [float(jnp.abs(a - b_).mean() / (jnp.abs(a).mean() + 1e-9))
           for a, b_ in zip(ref_l, q_l)]
    assert max(rel) < 0.05                    # but close


def test_server_optimizers_update(setup):
    m, params, batches = setup
    for name in ("fedadam", "fedyogi"):
        fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1)
        sopt = get_server_optimizer(name)
        step = jax.jit(build_fl_round_step(
            m.loss_fn, get_client_optimizer("sgd"), sopt, fl))
        state = sopt.init(params)
        p, state, _ = step(params, state, batches, jnp.ones((C,)),
                           jnp.ones((C,)), jax.random.PRNGKey(2))
        moved = any(float(jnp.abs(a - b_).max()) > 0
                    for a, b_ in zip(jax.tree.leaves(p), jax.tree.leaves(params)))
        assert moved, name


def test_hierarchical_matches_flat_when_uncompressed(setup):
    m, params, batches = setup
    kw = dict(num_clients=C, local_steps=H, client_lr=0.1)
    flat = FLConfig(**kw)
    hier = FLConfig(hierarchical=True, **kw)
    opt, sopt = get_client_optimizer("sgd"), get_server_optimizer("fedavg")
    s1 = jax.jit(build_fl_round_step(m.loss_fn, opt, sopt, flat, n_pods=1))
    s2 = jax.jit(build_fl_round_step(m.loss_fn, opt, sopt, hier, n_pods=2))
    args = ((), batches, jnp.ones((C,)), jnp.ones((C,)), jax.random.PRNGKey(2))
    p1 = s1(params, *args)[0]
    p2 = s2(params, *args)[0]
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-6)


def test_parallel_under_mesh_requires_spmd_axes(setup):
    # vmapping clients without spmd_axis_name while a mesh is active is the
    # layout that made GSPMD mis-partition the scan transpose (wrong primal
    # loss) — the builder must reject it loudly at build time
    m, _, _ = setup
    from repro.models import sharding as sh
    mesh = jax.make_mesh((1,), ("data",))
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                  client_exec="parallel")
    with sh.use_mesh(mesh):
        with pytest.raises(ValueError, match="client_spmd_axes"):
            build_fl_round_step(m.loss_fn, get_client_optimizer("sgd"),
                                get_server_optimizer("fedavg"), fl)
        # declaring the mapped axes is the supported layout
        build_fl_round_step(m.loss_fn, get_client_optimizer("sgd"),
                            get_server_optimizer("fedavg"), fl,
                            client_spmd_axes="data")
