"""Always-on parity suite for the fused commit path (ISSUE 7).

Three layers of pinning:

  * kernel vs jnp oracle (kernels/ref.py) on odd, padding-exercising
    shapes, bits {4, 8} — the Pallas kernels compute the same numbers.
  * the integer-domain SecAgg algebra: uint32 modular pairwise masks
    cancel EXACTLY in the summed wire words (bitwise, not allclose), with
    non-participating slots unwound.
  * fused vs unfused ``use_fused`` across all four execution regimes
    (sync parallel / sequential / pod_sequential via build_fl_round_step,
    async buffered commit via build_buffer_commit_step): <= 1e-5 on the
    committed params — the acceptance criterion of the ISSUE.
"""
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AsyncConfig, CompressionConfig, FLConfig,
                        build_buffer_commit_step, build_client_update_step,
                        build_fl_round_step, build_update_pipeline)
from repro.core import secure_agg as sec
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import build_model
from repro.optim import get_client_optimizer, get_server_optimizer

K = 4
ODD_SHAPES = [(17,), (2, 5, 9), (3, 300), (1,), (2049,)]


def _slots(shape, seed=0, scale=0.01):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(K,) + shape).astype(np.float32) * scale)
    w = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    s = jnp.asarray(rng.integers(0, 5, K).astype(np.float32))
    return x, w, s


def _close(t1, t2, tol=1e-5):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol)


def _block(x, block=256):
    """The ops._stack_blocks layout: last dim padded/blocked per slot row,
    leading dims collapsed -> [K, R, block]."""
    shp = x.shape[1:] or (1,)
    xx = x.reshape((x.shape[0], -1, shp[-1])).astype(jnp.float32)
    pad = (-shp[-1]) % block
    if pad:
        xx = jnp.pad(xx, ((0, 0), (0, 0), (0, pad)))
    return xx.reshape(x.shape[0], -1, block), pad, shp


def _unblock(y, pad, shp):
    return np.asarray(y).reshape(-1, shp[-1] + pad)[:, :shp[-1]].reshape(shp)


# ------------------------------------------------------ kernels vs oracles
@pytest.mark.parametrize("shape", ODD_SHAPES)
def test_fused_accum_matches_oracle(shape):
    x, w, s = _slots(shape)
    got = kops.fused_accum(x, w, s, 0.5)
    xb, pad, shp = _block(x)
    want = _unblock(kref.fused_accum_ref(xb, w[:, None], s[:, None], 0.5),
                    pad, shp)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)
    assert got.shape == shape


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("shape", [(515,), (3, 130)])
def test_fused_plain_commit_matches_oracle(bits, shape):
    x, w, s = _slots(shape, seed=bits)
    comp = CompressionConfig(quantize_bits=bits, topk_frac=0.1)
    got = kops.fused_plain_commit(x, w, s, 0.5, bits=bits, k=comp.topk_k)
    xb, pad, shp = _block(x, comp.block)
    want = _unblock(kref.fused_plain_commit_ref(
        xb, w[:, None], s[:, None], 0.5, bits, k=comp.topk_k), pad, shp)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [4, 8])
def test_fused_secure_commit_matches_oracle(bits):
    shape = (3, 300)
    x, w, _ = _slots(shape, seed=bits + 10)
    ids = jnp.arange(1, K + 1, dtype=jnp.uint32)
    part = jnp.asarray([1.0, 1.0, 0.0, 1.0], jnp.float32)
    seeds = sec.pair_seeds(jax.random.PRNGKey(3), ids)
    coef = sec.pair_coef_int(ids, part)
    got = kops.fused_secure_commit(x, w, seeds, coef, 7, bits=bits)
    xb, pad, shp = _block(x)
    want = _unblock(kref.fused_secure_commit_ref(
        xb, w[:, None], seeds, coef, 7, bits), pad, shp)
    # ulp-level only: the hand-called eager ref and the jitted wrapper may
    # reassociate the scale division differently; exactness is asserted on
    # same-executor properties (mask cancellation, executor swap below)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-8)
    swap = kops.fused_secure_commit(x, w, seeds, coef, 7, bits=bits,
                                    use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(swap),
                               rtol=1e-7, atol=1e-9)


def test_integer_masks_cancel_exactly():
    """uint32 modular masks cancel bitwise in the sum: the masked commit
    equals the coef-zeroed (unmasked) commit EXACTLY, including with a
    non-participating slot whose pair masks are unwound."""
    x, w, _ = _slots((4, 257), seed=5)
    ids = jnp.arange(1, K + 1, dtype=jnp.uint32)
    part = jnp.asarray([1.0, 1.0, 0.0, 1.0], jnp.float32)
    w = w * part                     # padded slot contributes nothing
    seeds = sec.pair_seeds(jax.random.PRNGKey(9), ids)
    coef = sec.pair_coef_int(ids, part)
    masked = kops.fused_secure_commit(x, w, seeds, coef, 0, bits=8)
    unmasked = kops.fused_secure_commit(x, w, seeds,
                                        jnp.zeros_like(coef), 0, bits=8)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(unmasked))


# ------------------------------------------------- leaf bucketing (PR 10)
def test_bucketed_tree_matches_per_leaf_bitwise():
    """The bucketed tree entry points (what core/pipeline dispatches) must
    equal per-leaf kernel calls BITWISE: rows are whole blocks of one leaf
    each, so block membership, per-block scales, top-k thresholds and the
    secure mask stream (bucket row-major index == per-leaf ``base``
    accumulation) are all unchanged — only the launch count collapses."""
    rng = np.random.default_rng(11)
    shapes = [(7,), (33, 9), (256,), (2, 5, 3), (515,)]
    leaves = [jnp.asarray(rng.normal(size=(K,) + s).astype(np.float32) * 0.01)
              for s in shapes]
    w = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    s = jnp.asarray(rng.integers(0, 5, K).astype(np.float32))

    for got, want in zip(kops.fused_accum_tree(leaves, w, s, 0.5),
                         [kops.fused_accum(l, w, s, 0.5) for l in leaves]):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    for got, want in zip(
            kops.fused_plain_commit_tree(leaves, w, s, 0.5, bits=8, k=26),
            [kops.fused_plain_commit(l, w, s, 0.5, bits=8, k=26)
             for l in leaves]):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    ids = jnp.arange(1, K + 1, dtype=jnp.uint32)
    seeds = sec.pair_seeds(jax.random.PRNGKey(3), ids)
    coef = sec.pair_coef_int(ids, jnp.ones((K,), jnp.float32))
    got_tree = kops.fused_secure_commit_tree(leaves, w, seeds, coef, bits=8)
    base = 0
    for got, leaf in zip(got_tree, leaves):
        want = kops.fused_secure_commit(leaf, w, seeds, coef, base, bits=8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        base += _block(leaf)[0].shape[1] * 256   # padded elements of leaf


def test_bucketed_tree_single_launch():
    rng = np.random.default_rng(12)
    leaves = [jnp.asarray(rng.normal(size=(K, 100 + 7 * i))
                          .astype(np.float32)) for i in range(8)]
    w = jnp.ones((K,), jnp.float32)
    s = jnp.zeros((K,), jnp.float32)
    kops.KERNEL_LAUNCHES = 0
    kops.fused_plain_commit_tree(leaves, w, s, 0.5, bits=8, k=26)
    assert kops.KERNEL_LAUNCHES == 1
    kops.KERNEL_LAUNCHES = 0
    [kops.fused_plain_commit(l, w, s, 0.5, bits=8, k=26) for l in leaves]
    assert kops.KERNEL_LAUNCHES == len(leaves)


# ------------------------------------- sharded == unsharded, bitwise (PR 10)
_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FLConfig, build_update_pipeline
from repro.core import secure_agg as sec
from repro.kernels import ops as kops
from repro.models import sharding as sh

K = 4
rng = np.random.default_rng(7)
# 2049 elements -> 9 blocks of 256: odd row count forces the shard_map
# wrappers through their pad-to-shard-multiple path
x = jnp.asarray(rng.normal(size=(K, 2049)).astype(np.float32) * 0.01)
w = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
s = jnp.asarray(rng.integers(0, 5, K).astype(np.float32))
ids = jnp.arange(1, K + 1, dtype=jnp.uint32)
part = jnp.asarray([1.0, 1.0, 0.0, 1.0], jnp.float32)
seeds = sec.pair_seeds(jax.random.PRNGKey(3), ids)
coef = sec.pair_coef_int(ids, part)
leaves = [x, jnp.asarray(rng.normal(size=(K, 3, 130)).astype(np.float32))]

ref = {
    "quant": kops.quantize_dequant(x[0], bits=8),
    "topk": kops.topk_sparsify(x[0], k=26),
    "accum": kops.fused_accum(x, w, s, 0.5),
    "plain": kops.fused_plain_commit(x, w, s, 0.5, bits=8, k=26),
    "secure": kops.fused_secure_commit(x, w, seeds, coef, 7, bits=8),
    "tree": kops.fused_secure_commit_tree(leaves, w, seeds, coef, bits=8),
}

mesh = jax.make_mesh((2,), ("data",))
out = {}
with sh.use_mesh(mesh):
    assert build_update_pipeline(FLConfig()).fused, "gate-lift regression"
    got = {
        "quant": kops.quantize_dequant(x[0], bits=8),
        "topk": kops.topk_sparsify(x[0], k=26),
        "accum": kops.fused_accum(x, w, s, 0.5),
        "plain": kops.fused_plain_commit(x, w, s, 0.5, bits=8, k=26),
        "secure": kops.fused_secure_commit(x, w, seeds, coef, 7, bits=8),
        "tree": kops.fused_secure_commit_tree(leaves, w, seeds, coef,
                                              bits=8),
    }
    for name in ref:
        out[name] = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(ref[name]), jax.tree.leaves(got[name])))
    # mask cancellation stays BITWISE with sharded PRF seeds: each shard
    # derives the mask stream from the GLOBAL element index (base + flat
    # shard offset), so masked == coef-zeroed exactly under the mesh
    masked = kops.fused_secure_commit(x, w * part, seeds, coef, 0, bits=8)
    unmasked = kops.fused_secure_commit(x, w * part, seeds,
                                        jnp.zeros_like(coef), 0, bits=8)
    out["mask_cancel"] = float(jnp.abs(masked - unmasked).max())
print(json.dumps(out))
"""


def test_sharded_matches_unsharded_bitwise():
    """Every fused entry point under an ACTIVE 2-device mesh must equal its
    no-mesh result BITWISE (row-sharding preserves block membership and all
    per-block quantities), and the integer mask stream must still cancel
    exactly with position-independent per-shard PRF bases."""
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(root / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=root,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {name: 0.0 for name in res}, res


# --------------------------------------- fused vs unfused, all four regimes
C, H, b, S = 4, 2, 2, 16
DET_COMP = dict(quantize_bits=8, topk_frac=0.1, stochastic_rounding=False)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-charlm").replace(n_layers=2, d_model=64, d_ff=128,
                                             n_heads=2, kv_heads=2)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (C, H, b, S + 1), 0,
                              cfg.vocab, jnp.int32)
    batches = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
    return m, params, batches


@pytest.mark.parametrize("exec_mode,secure", [
    ("parallel", False), ("parallel", True),
    ("sequential", False), ("sequential", True),
])
def test_sync_fused_matches_unfused(setup, exec_mode, secure):
    m, params, batches = setup
    outs = {}
    for use_fused in (True, False):
        comp = CompressionConfig(use_fused=use_fused, **DET_COMP)
        fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                      client_exec=exec_mode, secure_agg=secure,
                      compression=comp)
        step = jax.jit(build_fl_round_step(
            m.loss_fn, get_client_optimizer("sgd"),
            get_server_optimizer("fedavg"), fl))
        outs[use_fused] = step(params, (), batches,
                               jnp.asarray([1.0, 2.0, 3.0, 4.0]),
                               jnp.asarray([1.0, 0.0, 1.0, 1.0]),
                               jax.random.PRNGKey(2))
    _close(outs[True][0], outs[False][0])


def test_pod_sequential_fused_matches_unfused(setup):
    m, params, batches = setup
    outs = {}
    for use_fused in (True, False):
        comp = CompressionConfig(use_fused=use_fused, **DET_COMP)
        fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                      client_exec="pod_sequential", compression=comp)
        step = jax.jit(build_fl_round_step(
            m.loss_fn, get_client_optimizer("sgd"),
            get_server_optimizer("fedavg"), fl, n_pods=2))
        outs[use_fused] = step(params, (), batches,
                               jnp.asarray([1.0, 2.0, 3.0, 4.0]),
                               jnp.ones((C,)), jax.random.PRNGKey(2))
    _close(outs[True][0], outs[False][0])


@pytest.mark.parametrize("secure", [False, True])
def test_async_commit_fused_matches_unfused(setup, secure):
    m, params, batches = setup
    copt, sopt = get_client_optimizer("sgd"), get_server_optimizer("fedavg")
    rng = jax.random.PRNGKey(4)
    outs = {}
    for use_fused in (True, False):
        comp = CompressionConfig(use_fused=use_fused, **DET_COMP)
        fl = FLConfig(mode="async", num_clients=C, local_steps=H,
                      client_lr=0.1, secure_agg=secure, compression=comp)
        client_step = jax.jit(build_client_update_step(m.loss_fn, copt, fl))
        rngs = jax.random.split(rng, C)
        deltas = [client_step(params,
                              jax.tree.map(lambda x: x[c], batches),
                              rngs[c])[0] for c in range(C)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        commit = jax.jit(build_buffer_commit_step(
            sopt, fl, AsyncConfig(buffer_size=C)))
        outs[use_fused] = commit(
            params, (), stacked, jnp.asarray([1.0, 2.0, 3.0, 4.0]),
            jnp.asarray([0.0, 1.0, 3.0, 2.0]), jnp.zeros(C),
            jnp.asarray([1.0, 1.0, 0.0, 1.0]),
            jnp.arange(C, dtype=jnp.int32), jnp.float32(0.5), rng)
    _close(outs[True][0], outs[False][0])


def test_fused_masked_equals_plain_uncompressed(setup):
    """The pre-existing acceptance property survives fusion: with
    compression off and use_fused on (the default), a masked round equals
    the plain round to 1e-5 (float-domain masks vs fused accumulate)."""
    m, params, batches = setup
    outs = {}
    for secure in (False, True):
        fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                      secure_agg=secure)
        assert fl.compression.use_fused            # default on
        step = jax.jit(build_fl_round_step(
            m.loss_fn, get_client_optimizer("sgd"),
            get_server_optimizer("fedavg"), fl))
        outs[secure] = step(params, (), batches,
                            jnp.asarray([1.0, 2.0, 3.0, 4.0]),
                            jnp.asarray([1.0, 0.0, 1.0, 1.0]),
                            jax.random.PRNGKey(2))
    _close(outs[False][0], outs[True][0])


# ----------------------------------------------------------------- gating
def test_fusion_gates_off():
    cfg = FLConfig(compression=CompressionConfig(use_fused=False))
    assert build_update_pipeline(cfg).fused is False
    cfg = FLConfig()
    assert build_update_pipeline(cfg, allow_fused=False).fused is False
    assert build_update_pipeline(cfg).fused is True


def test_stochastic_rounding_uses_oracle_not_kernel(setup):
    """Stochastic quantize needs per-element randomness: the secure commit
    must route through the jnp oracle (noise path) and still cancel masks
    — masked equals coef-zeroed exactly."""
    x, w, _ = _slots((300,), seed=8)
    ids = jnp.arange(1, K + 1, dtype=jnp.uint32)
    seeds = sec.pair_seeds(jax.random.PRNGKey(2), ids)
    coef = sec.pair_coef_int(ids, jnp.ones((K,), jnp.float32))
    nr = jax.random.PRNGKey(6)
    masked = kops.fused_secure_commit(x, w, seeds, coef, 0, bits=8,
                                      noise_rng=nr)
    unmasked = kops.fused_secure_commit(x, w, seeds, jnp.zeros_like(coef),
                                        0, bits=8, noise_rng=nr)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(unmasked))
