"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step + one serve step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import build_model

pytestmark = pytest.mark.slow    # per-arch builds: minutes of CPU compile


def make_batch(cfg, B=2, S=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    shape = (B, S + 1, cfg.n_codebooks) if cfg.n_codebooks else (B, S + 1)
    toks = jax.random.randint(rng, shape, 0, cfg.vocab, jnp.int32)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.cross_attn_every:
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
class TestArchSmoke:
    def _get(self, models, arch):
        if arch not in models:
            cfg = reduced(get_config(arch))
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            models[arch] = (cfg, m, params)
        return models[arch]

    def test_train_step(self, models, arch):
        cfg, m, params = self._get(models, arch)
        batch = make_batch(cfg)
        (loss, metrics), grads = jax.value_and_grad(
            m.loss_fn, has_aux=True)(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), loss
        for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
            assert bool(jnp.isfinite(g).all()), path

    def test_prefill_decode_shapes(self, models, arch):
        cfg, m, params = self._get(models, arch)
        B, S = 2, 16
        batch = make_batch(cfg, B, S)
        pb = {"tokens": batch["tokens"]}
        if "patches" in batch:
            pb["patches"] = batch["patches"]
        lg, state = m.prefill(params, pb, s_max=S + 4)
        want = (B, cfg.n_codebooks, cfg.vocab) if cfg.n_codebooks else (B, cfg.vocab)
        assert lg.shape == want
        assert bool(jnp.isfinite(lg).all())
        tok = batch["targets"][:, -1]
        lg2, state2 = m.decode_step(params, state, tok, jnp.int32(S),
                                    batch.get("patches"))
        assert lg2.shape == want
        assert bool(jnp.isfinite(lg2).all())
        # state structure preserved
        jax.tree.map(lambda a, b: None, state, state2)

    def test_param_structure_specs_align(self, models, arch):
        cfg, m, params = self._get(models, arch)
        specs = m.logical_specs
        pleaves = jax.tree_util.tree_flatten_with_path(params)[0]
        sleaves = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, tuple))[0]
        assert len(pleaves) == len(sleaves)
        for (pp, pv), (sp, sv) in zip(pleaves, sleaves):
            assert pp == sp
            assert len(sv) == pv.ndim, (pp, sv, pv.shape)
