"""Secure aggregation properties under the commit-keyed pairwise masking
scheme: mask cancellation, privacy of individual updates, dropout/padding
unwinding, commit-key freshness, and jit-compatibility of the vectorised
masking path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.secure_agg import (aggregate_masked, commit_key, mask_batch,
                                   mask_update, masked_payload_bytes,
                                   pair_mask, secure_weighted_mean)


def updates(C=4, shape=(8, 16), seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(C,) + shape).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(C, shape[1])).astype(np.float32))}


def test_masks_cancel_exactly():
    C = 4
    ups = updates(C)
    key = commit_key(7)
    ids = jnp.arange(C, dtype=jnp.int32)
    part = jnp.ones((C,))
    masked = mask_batch(ups, key, ids, part)
    got = aggregate_masked(masked, part)
    want = jax.tree.map(lambda x: x.sum(0), ups)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def test_individual_updates_are_hidden():
    C = 4
    ups = updates(C)
    key = commit_key(11)
    ids = jnp.arange(C, dtype=jnp.int32)
    part = jnp.ones((C,))
    masked0 = mask_update(jax.tree.map(lambda x: x[0], ups), 0, key, ids, part)
    # the masked update must differ substantially from the raw one
    raw0 = jax.tree.map(lambda x: x[0], ups)
    for m, r in zip(jax.tree.leaves(masked0), jax.tree.leaves(raw0)):
        assert float(jnp.abs(m - r).mean()) > 0.5   # masks are O(sqrt(C)) noise


def test_dropout_unwinding():
    """Masks between pairs where one side dropped must not corrupt the sum."""
    C = 5
    ups = updates(C, seed=3)
    key = commit_key(13)
    ids = jnp.arange(C, dtype=jnp.int32)
    part = jnp.asarray([1.0, 1.0, 0.0, 1.0, 0.0])
    masked = mask_batch(ups, key, ids, part)
    got = aggregate_masked(masked, part)
    want = jax.tree.map(
        lambda x: (x * part.reshape((-1,) + (1,) * (x.ndim - 1))).sum(0), ups)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def test_nonparticipating_pair_masks_never_enter_the_sum():
    """Unit pin of the seed-reveal unwinding: slot i's total mask with slot
    j dropped equals the manual sum of its pair masks over PARTICIPATING
    peers only — the (i, j) pair mask is exactly absent, not merely
    cancelled."""
    C, shape = 5, (6, 4)
    key = commit_key(29)
    ids = jnp.arange(C, dtype=jnp.int32)
    part = jnp.asarray([1.0, 1.0, 0.0, 1.0, 1.0])     # slot 2 dropped
    zero = {"x": jnp.zeros((C,) + shape, jnp.float32)}
    masks = mask_batch(zero, key, ids, part)["x"]      # pure mask totals
    for i in range(C):
        want = np.zeros(shape, np.float32)
        if part[i]:
            for j in range(C):
                if j == i or not part[j]:
                    continue          # the dropped peer's mask must be absent
                sign = 1.0 if i < j else -1.0
                want += sign * np.asarray(pair_mask(key, ids[i], ids[j],
                                                    shape))
        np.testing.assert_allclose(np.asarray(masks[i]), want, rtol=1e-5,
                                   atol=1e-6)
    # and the dropped slot's own row is exactly zero
    np.testing.assert_allclose(np.asarray(masks[2]), 0.0)


def test_pair_masks_are_symmetric_and_commit_fresh():
    """key_ij == key_ji within a commit; a different commit id yields
    entirely different masks (no cross-commit reuse)."""
    shape = (8,)
    k1, k2 = commit_key(3), commit_key(4)
    m_ij = np.asarray(pair_mask(k1, 0, 5, shape))
    m_ji = np.asarray(pair_mask(k1, 5, 0, shape))
    np.testing.assert_allclose(m_ij, m_ji)
    m_other = np.asarray(pair_mask(k2, 0, 5, shape))
    assert np.abs(m_ij - m_other).max() > 0.1


def test_mask_batch_jits_and_matches_eager():
    """The vectorised masking path must jit (the old per-pair Python loop
    did not) and agree with its eager evaluation."""
    C = 6
    ups = updates(C, seed=9)
    key = commit_key(17)
    ids = jnp.arange(C, dtype=jnp.int32)
    part = jnp.asarray([1.0, 0.0, 1.0, 1.0, 1.0, 0.0])
    jitted = jax.jit(mask_batch)(ups, key, ids, part)
    eager = mask_batch(ups, key, ids, part)
    for a, b in zip(jax.tree.leaves(jitted), jax.tree.leaves(eager)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_secure_weighted_mean_matches_plain():
    C = 4
    ups = updates(C, seed=5)
    key = commit_key(19)
    part = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    weights = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    got = secure_weighted_mean(ups, weights, part, key)
    denom = float((weights * part).sum())
    want = jax.tree.map(
        lambda x: (x * (weights * part).reshape((-1,) + (1,) * (x.ndim - 1))
                   ).sum(0) / denom, ups)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def test_masked_payload_is_dense_f32():
    """Masking destroys compression savings: the masked wire size is 4
    bytes/element regardless of leaf dtype or compression config."""
    tree = {"a": jnp.zeros((3, 5), jnp.float32),
            "b": jnp.zeros((7,), jnp.bfloat16)}
    assert masked_payload_bytes(tree) == (3 * 5 + 7) * 4


def test_duplicate_ids_cancel_but_leave_a_privacy_hole():
    """Regression pin for WHY callers key masks on unique per-commit slot
    indices.  Cancellation is robust either way (the signed pair
    coefficients are antisymmetric per slot pair), but two slots sharing
    an id — a fast client landing two updates in one async commit, under
    cid keying — derive sign 0 for their mutual pair and exchange NO mask
    at all: each sees the other's barely-masked residual.  Unique slot
    ids (``_stack_buffer``'s contract) mask every live pair."""
    shape = (16,)
    key = commit_key(23)
    zero = {"x": jnp.zeros((3,) + shape, jnp.float32)}
    part = jnp.ones((3,))
    dup = jnp.asarray([0, 0, 7], jnp.int32)       # one client, two slots
    m_dup = mask_batch(zero, key, dup, part)["x"]
    summed = aggregate_masked({"x": m_dup}, part)["x"]
    np.testing.assert_allclose(np.asarray(summed), 0.0, atol=1e-4)
    # ... but the duplicate slots carry IDENTICAL mask totals: their mutual
    # pair is unmasked, so subtracting exposes both raw updates
    np.testing.assert_allclose(np.asarray(m_dup[0]), np.asarray(m_dup[1]))
    uniq = jnp.asarray([0, 1, 2], jnp.int32)       # slot-index keying
    m_uniq = mask_batch(zero, key, uniq, part)["x"]
    assert float(jnp.abs(m_uniq[0] - m_uniq[1]).max()) > 0.1
    np.testing.assert_allclose(
        np.asarray(aggregate_masked({"x": m_uniq}, part)["x"]), 0.0,
        atol=1e-4)
