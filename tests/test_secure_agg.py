"""Secure aggregation properties: mask cancellation, privacy of individual
updates, dropout unwinding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.secure_agg import (aggregate_masked, mask_update,
                                   pairwise_seeds, secure_weighted_mean)


def updates(C=4, shape=(8, 16), seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(C,) + shape).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(C, shape[1])).astype(np.float32))}


def test_masks_cancel_exactly():
    C = 4
    ups = updates(C)
    seeds = pairwise_seeds(7, C)
    part = jnp.ones((C,))
    masked = [mask_update(jax.tree.map(lambda x: x[i], ups), i, seeds, part)
              for i in range(C)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *masked)
    got = aggregate_masked(stacked, part)
    want = jax.tree.map(lambda x: x.sum(0), ups)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def test_individual_updates_are_hidden():
    C = 4
    ups = updates(C)
    seeds = pairwise_seeds(11, C)
    part = jnp.ones((C,))
    masked0 = mask_update(jax.tree.map(lambda x: x[0], ups), 0, seeds, part)
    # the masked update must differ substantially from the raw one
    raw0 = jax.tree.map(lambda x: x[0], ups)
    for m, r in zip(jax.tree.leaves(masked0), jax.tree.leaves(raw0)):
        assert float(jnp.abs(m - r).mean()) > 0.5   # masks are O(sqrt(C)) noise


def test_dropout_unwinding():
    """Masks between pairs where one side dropped must not corrupt the sum."""
    C = 5
    ups = updates(C, seed=3)
    seeds = pairwise_seeds(13, C)
    part = jnp.asarray([1.0, 1.0, 0.0, 1.0, 0.0])
    masked = [mask_update(jax.tree.map(lambda x: x[i], ups), i, seeds, part)
              for i in range(C)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *masked)
    got = aggregate_masked(stacked, part)
    want = jax.tree.map(
        lambda x: (x * part.reshape((-1,) + (1,) * (x.ndim - 1))).sum(0), ups)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def test_secure_weighted_mean_matches_plain():
    C = 4
    ups = updates(C, seed=5)
    seeds = pairwise_seeds(17, C)
    part = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    weights = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    got = secure_weighted_mean(ups, weights, part, seeds)
    denom = float((weights * part).sum())
    want = jax.tree.map(
        lambda x: (x * (weights * part).reshape((-1,) + (1,) * (x.ndim - 1))
                   ).sum(0) / denom, ups)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)
