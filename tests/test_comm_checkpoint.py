"""Comm layer accounting + payload serialization + checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.comm import (CommAccountant, DCN, GRPC_CLOUD, ICI, MPI_HPC,
                        deserialize_tree, link_for_site, serialize_tree,
                        tree_bytes)


def test_link_transfer_times_ordered():
    nb = 100e6
    assert ICI.transfer_time(nb) < MPI_HPC.transfer_time(nb) \
        < DCN.transfer_time(nb) < GRPC_CLOUD.transfer_time(nb)
    assert link_for_site("hpc") is MPI_HPC
    assert link_for_site("cloud") is GRPC_CLOUD


def test_accountant_aggregates():
    acc = CommAccountant()
    for rnd in range(3):
        for cid in range(4):
            acc.log(rnd, cid, "up", 1000, MPI_HPC)
            acc.log(rnd, cid, "down", 500, MPI_HPC)
    assert acc.total_bytes() == 3 * 4 * 1500
    assert acc.bytes_per_round() == {0: 6000, 1: 6000, 2: 6000}
    assert acc.mean_bytes_per_client_round() == 1000


def tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int32),
                  "d": np.float32(3.5) * np.ones((), np.float32)}}


def test_serialize_roundtrip():
    t = tree()
    data = serialize_tree(t)
    back = deserialize_tree(data, like=t)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(x, y)
    assert tree_bytes(t) == 12 * 4 + 5 * 4 + 4


def test_save_load_pytree(tmp_path):
    t = jax.tree.map(jnp.asarray, tree())
    save_pytree(tmp_path / "x.bin", t)
    back = load_pytree(tmp_path / "x.bin", t)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_manager_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = tree()
    for rnd in (0, 5, 10):
        mgr.save(rnd, t, meta={"clock": rnd * 1.5})
    assert mgr.latest_round() == 10
    params, state, meta = mgr.restore(t)
    assert meta["round"] == 10 and meta["clock"] == 15.0
    dirs = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
    assert dirs == ["round_000005", "round_000010"]   # keep=2 gc'd round 0


def test_checkpoint_resume_cycle(tmp_path):
    """Orchestrator restart: params + server state resume bit-exact."""
    mgr = CheckpointManager(tmp_path)
    params = {"w": np.random.default_rng(0).normal(size=(4, 4)).astype(np.float32)}
    sstate = {"m": {"w": np.ones((4, 4), np.float32)}}
    mgr.save(7, params, sstate)
    p2, s2, meta = mgr.restore(params, sstate)
    np.testing.assert_array_equal(p2["w"], params["w"])
    np.testing.assert_array_equal(s2["m"]["w"], sstate["m"]["w"])
