"""Comm layer accounting + payload serialization + checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.comm import (CommAccountant, DCN, GRPC_CLOUD, ICI, MPI_HPC,
                        deserialize_tree, link_for_site, serialize_tree,
                        tree_bytes)


def test_link_transfer_times_ordered():
    nb = 100e6
    assert ICI.transfer_time(nb) < MPI_HPC.transfer_time(nb) \
        < DCN.transfer_time(nb) < GRPC_CLOUD.transfer_time(nb)
    assert link_for_site("hpc") is MPI_HPC
    assert link_for_site("cloud") is GRPC_CLOUD


def test_accountant_aggregates():
    acc = CommAccountant()
    for rnd in range(3):
        for cid in range(4):
            acc.log(rnd, cid, "up", 1000, MPI_HPC)
            acc.log(rnd, cid, "down", 500, MPI_HPC)
    assert acc.total_bytes() == 3 * 4 * 1500
    assert acc.bytes_per_round() == {0: 6000, 1: 6000, 2: 6000}
    assert acc.mean_bytes_per_client_round() == 1000


def tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int32),
                  "d": np.float32(3.5) * np.ones((), np.float32)}}


def test_serialize_roundtrip():
    t = tree()
    data = serialize_tree(t)
    back = deserialize_tree(data, like=t)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(x, y)
    assert tree_bytes(t) == 12 * 4 + 5 * 4 + 4


def test_save_load_pytree(tmp_path):
    t = jax.tree.map(jnp.asarray, tree())
    save_pytree(tmp_path / "x.bin", t)
    back = load_pytree(tmp_path / "x.bin", t)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_manager_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = tree()
    for rnd in (0, 5, 10):
        mgr.save(rnd, t, meta={"clock": rnd * 1.5})
    assert mgr.latest_round() == 10
    params, state, meta = mgr.restore(t)
    assert meta["round"] == 10 and meta["clock"] == 15.0
    dirs = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
    assert dirs == ["round_000005", "round_000010"]   # keep=2 gc'd round 0


def test_checkpoint_resume_cycle(tmp_path):
    """Orchestrator restart: params + server state resume bit-exact."""
    mgr = CheckpointManager(tmp_path)
    params = {"w": np.random.default_rng(0).normal(size=(4, 4)).astype(np.float32)}
    sstate = {"m": {"w": np.ones((4, 4), np.float32)}}
    mgr.save(7, params, sstate)
    p2, s2, meta = mgr.restore(params, sstate)
    np.testing.assert_array_equal(p2["w"], params["w"])
    np.testing.assert_array_equal(s2["m"]["w"], sstate["m"]["w"])


def test_serialize_roundtrip_empty_trees():
    """Leaf-less pytrees survive the wire format: structure in, structure out."""
    for t in ((), {}, {"a": {}, "b": ()}):
        back = deserialize_tree(serialize_tree(t), like=t)
        assert jax.tree.leaves(back) == []
        assert jax.tree.structure(back) == jax.tree.structure(t)


def test_serialize_roundtrip_int_bool_dtypes():
    t = {"step": np.int64(7) * np.ones((), np.int64),
         "epoch": np.arange(5, dtype=np.int32),
         "warm": np.array([True, False, True]),
         "bits": np.arange(4, dtype=np.uint8),
         "m": np.zeros((2, 2), np.float32)}
    back = deserialize_tree(serialize_tree(t), like=t)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(x, y)


def test_checkpoint_saves_leafless_server_state(tmp_path):
    """A fedavg-style () opt state must be saved, not silently skipped —
    restoring it yields () (state present, empty), never None (no state)."""
    mgr = CheckpointManager(tmp_path)
    params = {"w": np.ones(3, np.float32)}
    mgr.save(4, params, server_state=(), meta={"clock": 2.5})
    assert (tmp_path / "round_000004" / "server_state.bin").exists()
    p2, s2, meta = mgr.restore(params, server_state_like=())
    assert s2 == () and s2 is not None
    assert meta["round"] == 4 and meta["clock"] == 2.5


def test_checkpoint_server_state_int_bool_leaves(tmp_path):
    mgr = CheckpointManager(tmp_path)
    params = {"w": np.zeros(2, np.float32)}
    sstate = {"step": np.ones((), np.int64), "done": np.zeros(3, bool)}
    mgr.save(1, params, sstate)
    _, s2, _ = mgr.restore(params, sstate)
    assert s2["step"].dtype == np.int64 and s2["done"].dtype == np.bool_
    np.testing.assert_array_equal(s2["step"], sstate["step"])
    np.testing.assert_array_equal(s2["done"], sstate["done"])
