"""Small-mesh sharding integration: run the dry-run machinery on an
8-placeholder-device (2,2,2) mesh in a subprocess (XLA device count is
locked at first jax init, so this cannot run in the main test process) and
EXECUTE one real FL round under the mesh to prove numerics survive
sharding."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow    # 8-device SPMD subprocesses: ~2 min each

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core import CompressionConfig, FLConfig, build_fl_round_step
from repro.launch import specs as sp
from repro.models import build_model, sharding as sh
from repro.optim import get_client_optimizer, get_server_optimizer

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
assert len(jax.devices()) == 8

cfg = reduced(get_config("%(arch)s"))
m = build_model(cfg)
C, H, b, S = 4, 2, 2, 16

with sh.use_mesh(mesh):
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.05,
                  fedprox_mu=0.01, client_exec="%(exec)s",
                  compression=CompressionConfig(quantize_bits=8),
                  accum_dtype="float32")
    # parallel mode MUST declare the mesh axes the vmapped client dim is
    # sharded over (the production layout — launch.dryrun does the same).
    # vmapping WITHOUT spmd_axis_name while the params carry full shardings
    # is an unsupported layout: GSPMD mis-partitions the scan transpose and
    # the primal loss itself comes out wrong (this is what the old xfail on
    # xlstm/parallel was really masking).
    spmd = ("pod", "data") if "%(exec)s" == "parallel" else None
    step = build_fl_round_step(m.loss_fn, get_client_optimizer("sgd"),
                               get_server_optimizer("fedavg"), fl, n_pods=2,
                               client_spmd_axes=spmd)
    params = m.init(jax.random.PRNGKey(0))
    param_sh = sp.sanitize_specs(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        m.logical_specs, mesh)
    params = jax.device_put(params, param_sh)
    shape = (C, H, b, S + 1, cfg.n_codebooks) if cfg.n_codebooks else (C, H, b, S + 1)
    toks = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab, jnp.int32)
    batches = {"tokens": toks[..., :-1, :] if cfg.n_codebooks else toks[..., :-1],
               "targets": toks[..., 1:, :] if cfg.n_codebooks else toks[..., 1:]}
    if cfg.cross_attn_every:
        batches["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (C, H, b, cfg.n_patches, cfg.d_model), jnp.float32)
    if spmd:
        # client dim sharded over pod x data, matching client_spmd_axes
        batches = jax.tree.map(lambda x: jax.device_put(
            x, NamedSharding(mesh, P(spmd, *(None,) * (x.ndim - 1)))), batches)
        batch_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, P(spmd, *(None,) * (x.ndim - 1))), batches)
    else:
        batch_sh = None
    with mesh:
        jstep = jax.jit(step, in_shardings=(param_sh, None, batch_sh, None, None, None),
                        out_shardings=(param_sh, None, None))
        p1, _, metrics = jstep(params, (), batches, jnp.ones((C,)),
                               jnp.ones((C,)), jax.random.PRNGKey(3))
    sharded_loss = float(metrics["client_loss"])

# reference: same round on a single device (no mesh)
sh.set_mesh(None)
step_ref = jax.jit(build_fl_round_step(
    m.loss_fn, get_client_optimizer("sgd"), get_server_optimizer("fedavg"),
    FLConfig(num_clients=C, local_steps=H, client_lr=0.05, fedprox_mu=0.01,
             client_exec="sequential",
             compression=CompressionConfig(quantize_bits=8),
             accum_dtype="float32")))
params_ref = jax.device_put(jax.tree.map(np.asarray, params), jax.devices()[0])
batches_ref = jax.tree.map(np.asarray, batches)
p2, _, metrics2 = step_ref(params_ref, (), batches_ref, jnp.ones((C,)),
                           jnp.ones((C,)), jax.random.PRNGKey(3))
ref_loss = float(metrics2["client_loss"])

err = max(float(jnp.abs(a.astype(jnp.float32) - np.asarray(b2, np.float32)).max())
          for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print(json.dumps({"sharded_loss": sharded_loss, "ref_loss": ref_loss,
                  "max_param_err": err}))
"""


def run_case(arch: str, exec_mode: str, param_tol: float):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"arch": arch, "exec": exec_mode}],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["sharded_loss"] - res["ref_loss"]) < 5e-3, res
    assert res["max_param_err"] < param_tol, res
    return res


# param_tol: dense archs differ only by ~2 int8-quantization steps on
# isolated elements (sharded reductions reorder the per-block max; a 1-ulp
# scale change can flip a rounding boundary — losses still match to 5e-3).
# MoE additionally has topology-dependent capacity semantics (per-shard
# capacity rounding changes which tokens drop — true of real EP systems),
# so its tolerance is wider.
@pytest.mark.parametrize("arch,exec_mode,param_tol", [
    ("granite-3-2b", "sequential", 3e-2),
    ("granite-3-2b", "pod_sequential", 3e-2),
    ("qwen3-moe-235b-a22b", "sequential", 2e-1),
    # xlstm/parallel exercises the head-sharded shard_map sLSTM scan: the
    # recurrence is block-diagonal per head, so each model shard owns whole
    # heads and the r* cotangents accumulate shard-locally (the GSPMD scan
    # transpose used to mis-accumulate them when r* was e-dim sharded).
    ("xlstm-125m", "parallel", 3e-2),
    # xlstm/sequential is the case the PR 10 sequential-mode audit fixed:
    # without the POD exclusion in round_sequential its mlstm grads come
    # out O(1) wrong on this pod-extent-2 mesh (see test_pod_axis_grad_pin)
    ("xlstm-125m", "sequential", 3e-2),
])
def test_sharded_round_matches_unsharded(arch, exec_mode, param_tol):
    run_case(arch, exec_mode, param_tol)


# --------------------------------------------------------------------------
# PR 10 sequential-mode GSPMD audit: pinned minimal repro.
#
# Root cause (bisected, see round.py round_sequential): jitting a direct
# value_and_grad of the xlstm loss with params sharded by their full specs
# on a mesh whose POD axis has extent > 1 miscompiles the BACKWARD — the
# primal loss stays BITWISE-exact while mlstm gradients (worst leaf ~2.3
# relative) are corrupt.  Characterisation:
#   * needs pod extent > 1: meshes (1,2,2)/(1,1,2)/(1,2,1) are exact with
#     the same full specs, at any batch size (even uneven b=1);
#   * triggered by params whose LAST dim is sharded over batch-participating
#     axes — e.g. mlstm up ("data","model") or ("model",)-style layouts with
#     an extra ("data",) constraint; (data,None)/(None,model)/down/all-sLSTM
#     layouts are clean; granite on the same mesh passes at 3e-2;
#   * needs the real mlstm block structure (standalone matmul/scan chains
#     do not reproduce) — i.e. an XLA GSPMD transpose bug, not repo math.
# Mitigation (asserted here and applied in round_sequential): exclude POD
# from activation constraints during sequential-mode local training —
# restores grads to float accuracy (~2e-5 worst-leaf relative, ulp-level
# reassociation from the different GSPMD reduction order).  If rel_bad
# ever drops below 0.1, the upstream miscompile was fixed and the
# exclusion can be reconsidered.
# --------------------------------------------------------------------------
PIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch import specs as sp
from repro.models import build_model, sharding as sh

cfg = reduced(get_config("xlstm-125m"))
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab,
                          jnp.int32)
batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
loss_ref, grads_ref = jax.value_and_grad(
    lambda p: m.loss_fn(p, batch)[0])(params)


def worst_rel(grads_m):
    flat_r = jax.tree.leaves(grads_ref)
    flat_m = jax.tree.leaves(grads_m)
    return max(
        float(np.abs(np.asarray(gr, np.float64)
                     - np.asarray(gm, np.float64)).max()
              / (np.abs(np.asarray(gr, np.float64)).max() + 1e-12))
        for gr, gm in zip(flat_r, flat_m))


def run(exclude_pod):
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with sh.use_mesh(mesh):
        param_sh = sp.sanitize_specs(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         params), m.logical_specs, mesh)
        p_dev = jax.device_put(params, param_sh)
        with mesh:
            def fn(p, bt):
                if exclude_pod:
                    with sh.exclude_axes(sh.POD):
                        return jax.value_and_grad(
                            lambda q: m.loss_fn(q, bt)[0])(p)
                return jax.value_and_grad(lambda q: m.loss_fn(q, bt)[0])(p)
            loss_m, grads_m = jax.jit(fn, in_shardings=(param_sh, None))(
                p_dev, batch)
    return float(loss_m), worst_rel(grads_m)


loss_bad, rel_bad = run(False)
loss_fix, rel_fix = run(True)
print(json.dumps({"loss_ref": float(loss_ref), "loss_bad": loss_bad,
                  "loss_fix": loss_fix, "rel_bad": rel_bad,
                  "rel_fix": rel_fix}))
"""


def test_pod_axis_grad_pin():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", PIN_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # the miscompile corrupts ONLY the backward: primal loss bitwise-exact
    assert res["loss_bad"] == res["loss_ref"], res
    assert res["loss_fix"] == res["loss_ref"], res
    # mitigation: POD excluded -> grads float-accurate (reassociation only)
    assert res["rel_fix"] < 1e-3, res
    # the pin: full specs on a pod-extent-2 mesh corrupt xlstm grads.  If
    # this flips, the upstream XLA GSPMD transpose bug got fixed — the
    # round_sequential POD exclusion can then be reconsidered.
    assert res["rel_bad"] > 0.1, res


# --------------------------------------------------------------------------
# PR 10 gate-lift acceptance: with an ACTIVE mesh, UpdatePipeline.fused
# stays True and fused == unfused <= 1e-5 across all four execution regimes
# (sync parallel / sequential / pod_sequential + async buffered commit) —
# the shard_mapped kernels replace the old mesh-forced unfused fallback.
# --------------------------------------------------------------------------
FUSED_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (AsyncConfig, CompressionConfig, FLConfig,
                        build_buffer_commit_step, build_client_update_step,
                        build_fl_round_step, build_update_pipeline)
from repro.models import build_model, sharding as sh
from repro.optim import get_client_optimizer, get_server_optimizer

MESH_SHAPE = %(mesh)s
cfg = get_config("paper-charlm").replace(n_layers=2, d_model=64, d_ff=128,
                                         n_heads=2, kv_heads=2)
m = build_model(cfg)
C, H, b, S = 4, 2, 2, 16
params = m.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (C, H, b, S + 1), 0,
                          cfg.vocab, jnp.int32)
batches = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
DET = dict(quantize_bits=8, topk_frac=0.1, stochastic_rounding=False)
mesh = jax.make_mesh(MESH_SHAPE, ("data", "model"))
copt, sopt = get_client_optimizer("sgd"), get_server_optimizer("fedavg")
report = {}


def diff(t1, t2):
    return max(float(jnp.abs(a - b2).max())
               for a, b2 in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)))


with sh.use_mesh(mesh), mesh:
    assert build_update_pipeline(FLConfig()).fused, "gate-lift regression"
    for exec_mode, secure in [("parallel", True), ("sequential", False),
                              ("pod_sequential", False)]:
        outs = {}
        for use_fused in (True, False):
            fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                          client_exec=exec_mode, secure_agg=secure,
                          compression=CompressionConfig(use_fused=use_fused,
                                                        **DET))
            spmd = (("data",) if exec_mode in ("parallel", "pod_sequential")
                    else None)
            step = jax.jit(build_fl_round_step(
                m.loss_fn, copt, sopt, fl, n_pods=2, client_spmd_axes=spmd))
            outs[use_fused] = step(params, (), batches,
                                   jnp.asarray([1.0, 2.0, 3.0, 4.0]),
                                   jnp.asarray([1.0, 0.0, 1.0, 1.0]),
                                   jax.random.PRNGKey(2))[0]
        report["sync_" + exec_mode] = diff(outs[True], outs[False])

    rng = jax.random.PRNGKey(4)
    outs = {}
    for use_fused in (True, False):
        fl = FLConfig(mode="async", num_clients=C, local_steps=H,
                      client_lr=0.1, secure_agg=True,
                      compression=CompressionConfig(use_fused=use_fused,
                                                    **DET))
        client_step = jax.jit(build_client_update_step(m.loss_fn, copt, fl))
        rngs = jax.random.split(rng, C)
        deltas = [client_step(params, jax.tree.map(lambda x: x[c], batches),
                              rngs[c])[0] for c in range(C)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        commit = jax.jit(build_buffer_commit_step(
            sopt, fl, AsyncConfig(buffer_size=C)))
        outs[use_fused] = commit(
            params, (), stacked, jnp.asarray([1.0, 2.0, 3.0, 4.0]),
            jnp.asarray([0.0, 1.0, 3.0, 2.0]), jnp.zeros(C),
            jnp.asarray([1.0, 1.0, 0.0, 1.0]),
            jnp.arange(C, dtype=jnp.int32), jnp.float32(0.5), rng)[0]
    report["async_buffered"] = diff(outs[True], outs[False])
print(json.dumps(report))
"""


@pytest.mark.parametrize("mesh_shape", [(1, 2), (2, 2)])
def test_fused_matches_unfused_under_mesh(mesh_shape):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", FUSED_MESH_SCRIPT % {"mesh": repr(mesh_shape)}],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert set(res) == {"sync_parallel", "sync_sequential",
                        "sync_pod_sequential", "async_buffered"}
    # Tolerance: the fused kernels are bitwise shard-invariant (pinned in
    # test_fused_kernels.py::test_sharded_matches_unsharded_bitwise) and
    # fused == unfused is BITWISE with no mesh; under a mesh the UNFUSED
    # jnp stack's GSPMD lowering reassociates (~1e-5 on this workload),
    # and near an int8 boundary that flips a rounding step (~1.3e-5 of
    # delta per step here).  Measured: parallel/async 0.0, sequential
    # 2.3e-5, pod_sequential 3.9e-5 — i.e. <= ~3 quantize steps; 5e-5
    # bounds that without masking real divergence.
    for regime, err in res.items():
        assert err <= 5e-5, (regime, res)
