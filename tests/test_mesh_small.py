"""Small-mesh sharding integration: run the dry-run machinery on an
8-placeholder-device (2,2,2) mesh in a subprocess (XLA device count is
locked at first jax init, so this cannot run in the main test process) and
EXECUTE one real FL round under the mesh to prove numerics survive
sharding."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow    # 8-device SPMD subprocesses: ~2 min each

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core import CompressionConfig, FLConfig, build_fl_round_step
from repro.launch import specs as sp
from repro.models import build_model, sharding as sh
from repro.optim import get_client_optimizer, get_server_optimizer

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
assert len(jax.devices()) == 8

cfg = reduced(get_config("%(arch)s"))
m = build_model(cfg)
C, H, b, S = 4, 2, 2, 16

with sh.use_mesh(mesh):
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.05,
                  fedprox_mu=0.01, client_exec="%(exec)s",
                  compression=CompressionConfig(quantize_bits=8),
                  accum_dtype="float32")
    # parallel mode MUST declare the mesh axes the vmapped client dim is
    # sharded over (the production layout — launch.dryrun does the same).
    # vmapping WITHOUT spmd_axis_name while the params carry full shardings
    # is an unsupported layout: GSPMD mis-partitions the scan transpose and
    # the primal loss itself comes out wrong (this is what the old xfail on
    # xlstm/parallel was really masking).
    spmd = ("pod", "data") if "%(exec)s" == "parallel" else None
    step = build_fl_round_step(m.loss_fn, get_client_optimizer("sgd"),
                               get_server_optimizer("fedavg"), fl, n_pods=2,
                               client_spmd_axes=spmd)
    params = m.init(jax.random.PRNGKey(0))
    param_sh = sp.sanitize_specs(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        m.logical_specs, mesh)
    params = jax.device_put(params, param_sh)
    shape = (C, H, b, S + 1, cfg.n_codebooks) if cfg.n_codebooks else (C, H, b, S + 1)
    toks = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab, jnp.int32)
    batches = {"tokens": toks[..., :-1, :] if cfg.n_codebooks else toks[..., :-1],
               "targets": toks[..., 1:, :] if cfg.n_codebooks else toks[..., 1:]}
    if cfg.cross_attn_every:
        batches["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (C, H, b, cfg.n_patches, cfg.d_model), jnp.float32)
    if spmd:
        # client dim sharded over pod x data, matching client_spmd_axes
        batches = jax.tree.map(lambda x: jax.device_put(
            x, NamedSharding(mesh, P(spmd, *(None,) * (x.ndim - 1)))), batches)
        batch_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, P(spmd, *(None,) * (x.ndim - 1))), batches)
    else:
        batch_sh = None
    with mesh:
        jstep = jax.jit(step, in_shardings=(param_sh, None, batch_sh, None, None, None),
                        out_shardings=(param_sh, None, None))
        p1, _, metrics = jstep(params, (), batches, jnp.ones((C,)),
                               jnp.ones((C,)), jax.random.PRNGKey(3))
    sharded_loss = float(metrics["client_loss"])

# reference: same round on a single device (no mesh)
sh.set_mesh(None)
step_ref = jax.jit(build_fl_round_step(
    m.loss_fn, get_client_optimizer("sgd"), get_server_optimizer("fedavg"),
    FLConfig(num_clients=C, local_steps=H, client_lr=0.05, fedprox_mu=0.01,
             client_exec="sequential",
             compression=CompressionConfig(quantize_bits=8),
             accum_dtype="float32")))
params_ref = jax.device_put(jax.tree.map(np.asarray, params), jax.devices()[0])
batches_ref = jax.tree.map(np.asarray, batches)
p2, _, metrics2 = step_ref(params_ref, (), batches_ref, jnp.ones((C,)),
                           jnp.ones((C,)), jax.random.PRNGKey(3))
ref_loss = float(metrics2["client_loss"])

err = max(float(jnp.abs(a.astype(jnp.float32) - np.asarray(b2, np.float32)).max())
          for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print(json.dumps({"sharded_loss": sharded_loss, "ref_loss": ref_loss,
                  "max_param_err": err}))
"""


def run_case(arch: str, exec_mode: str, param_tol: float):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"arch": arch, "exec": exec_mode}],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["sharded_loss"] - res["ref_loss"]) < 5e-3, res
    assert res["max_param_err"] < param_tol, res
    return res


# param_tol: dense archs differ only by ~2 int8-quantization steps on
# isolated elements (sharded reductions reorder the per-block max; a 1-ulp
# scale change can flip a rounding boundary — losses still match to 5e-3).
# MoE additionally has topology-dependent capacity semantics (per-shard
# capacity rounding changes which tokens drop — true of real EP systems),
# so its tolerance is wider.
@pytest.mark.parametrize("arch,exec_mode,param_tol", [
    ("granite-3-2b", "sequential", 3e-2),
    ("granite-3-2b", "pod_sequential", 3e-2),
    ("qwen3-moe-235b-a22b", "sequential", 2e-1),
    # xlstm/parallel exercises the head-sharded shard_map sLSTM scan: the
    # recurrence is block-diagonal per head, so each model shard owns whole
    # heads and the r* cotangents accumulate shard-locally (the GSPMD scan
    # transpose used to mis-accumulate them when r* was e-dim sharded).
    ("xlstm-125m", "parallel", 3e-2),
])
def test_sharded_round_matches_unsharded(arch, exec_mode, param_tol):
    run_case(arch, exec_mode, param_tol)
