"""End-to-end properties of the composable update pipeline with secure
aggregation wired through BOTH execution regimes.

The headline property (acceptance criterion): masked aggregation equals
plain aggregation to <= 1e-5 — for every sync exec mode, and for EVERY
commit of an async run that includes dropout faults, timeout
(partial-buffer) commits, and a mid-run kill/--resume.  Compression is
off in the equality runs so the plain and masked wire payloads coincide
and the two simulations follow identical event trajectories.
"""
import math
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointManager
from repro.configs import get_config
from repro.core import (AsyncConfig, FLConfig, build_buffer_commit_step,
                        build_client_update_step, build_fl_round_step,
                        build_update_pipeline)
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.models import build_model
from repro.models.cnn import CNN, CNNConfig
from repro.optim import get_client_optimizer, get_server_optimizer
from repro.orchestrator import (AsyncOrchestrator, FaultConfig, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet)

C, H, b, S = 4, 2, 2, 16
CNN_CFG = CNNConfig("tiny-cnn", (28, 28, 1), 9, channels=(4, 8), dense=32)
SEED, N_CLIENTS = 11, 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-charlm").replace(n_layers=2, d_model=64, d_ff=128,
                                             n_heads=2, kv_heads=2)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (C, H, b, S + 1), 0,
                              cfg.vocab, jnp.int32)
    batches = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
    return m, params, batches


def _round(setup, mask=None, n_pods=1, **kw):
    m, params, batches = setup
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1, **kw)
    step = jax.jit(build_fl_round_step(
        m.loss_fn, get_client_optimizer("sgd"),
        get_server_optimizer("fedavg"), fl, n_pods=n_pods))
    mask = jnp.ones((C,)) if mask is None else mask
    return step(params, (), batches, jnp.asarray([1.0, 2.0, 3.0, 4.0]),
                mask, jax.random.PRNGKey(2))


def _close(p1, p2, tol=1e-5):
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=tol, atol=tol)


# --------------------------------------------------------- sync exec modes
@pytest.mark.parametrize("exec_mode,n_pods", [
    ("parallel", 1), ("sequential", 1), ("pod_sequential", 2),
    ("parallel", 2),          # hierarchical pod path (masks between pods)
])
def test_secure_round_matches_plain_every_exec_mode(setup, exec_mode, n_pods):
    """Acceptance: --secure-agg changes what the server SEES, never what it
    LEARNS — masked round == plain round to 1e-5 in every exec mode,
    including with a dropped-out client (mask-0 pair unwinding)."""
    kw = dict(client_exec=exec_mode, n_pods=n_pods,
              hierarchical=(exec_mode == "parallel" and n_pods > 1))
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    p_plain, _, m_plain = _round(setup, mask=mask, **kw)
    p_sec, _, m_sec = _round(setup, mask=mask, secure_agg=True, **kw)
    _close(p_plain, p_sec)
    np.testing.assert_allclose(float(m_plain["client_loss"]),
                               float(m_sec["client_loss"]), rtol=1e-6)


def test_secure_rejects_trimmed_mean():
    with pytest.raises(ValueError, match="trimmed_mean"):
        build_update_pipeline(FLConfig(aggregation="trimmed_mean",
                                       secure_agg=True))


# ------------------------------------------- sync/async secure equivalence
def test_zero_staleness_secure_commit_equals_secure_sync_round(setup):
    """Acceptance: zero-staleness secure async still matches the sync round
    step — masking composes with the regime equivalence invariant."""
    m, params, batches = setup
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                  secure_agg=True)
    copt, sopt = get_client_optimizer("sgd"), get_server_optimizer("fedavg")
    sync_step = jax.jit(build_fl_round_step(m.loss_fn, copt, sopt, fl))
    weights, mask = jnp.ones((C,)), jnp.ones((C,))
    rng = jax.random.PRNGKey(2)
    p_sync, _, _ = sync_step(params, (), batches, weights, mask, rng)

    client_step = jax.jit(build_client_update_step(m.loss_fn, copt, fl))
    rngs = jax.random.split(rng, C)
    deltas = [client_step(params, jax.tree.map(lambda x: x[c], batches),
                          rngs[c])[0] for c in range(C)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
    commit = jax.jit(build_buffer_commit_step(
        sopt, fl, AsyncConfig(buffer_size=C)))
    p_async, _, _ = commit(params, (), stacked, weights, jnp.zeros(C),
                           jnp.zeros(C), mask,
                           jnp.arange(C, dtype=jnp.int32),
                           jnp.float32(0.5), rng)
    _close(p_sync, p_async)


# ---------------------------------------------------- orchestrated regimes
def make_async(secure, mgr=None, checkpoint_every=0, timeout=0.15,
               faults=None, seed=SEED, staleness_exponent=0.5):
    # timeout=0.15 sim-s vs a ~0.3 s/commit cadence: most commits flush a
    # PARTIAL buffer (mask-0 padded slots), a few still fill all K slots
    data = medmnist_like(n=400, seed=seed)
    parts = partition_dirichlet(data.y, N_CLIENTS, alpha=0.5, seed=seed)
    fed = FederatedDataset(data, parts, seed=seed)
    model = CNN(CNN_CFG)
    params = model.init(jax.random.PRNGKey(seed))
    fleet = make_hybrid_fleet(N_CLIENTS // 2, N_CLIENTS - N_CLIENTS // 2,
                              seed=seed, data_sizes=[len(p) for p in parts])
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=N_CLIENTS, local_steps=1,
                    client_lr=0.05, secure_agg=secure),
        async_cfg=AsyncConfig(buffer_size=3, commit_timeout_s=timeout,
                              max_concurrency=4, max_staleness=6,
                              staleness_exponent=staleness_exponent),
        straggler=StragglerPolicy(contention_sigma=0.5),
        faults=faults or FaultConfig(dropout_prob=0.25),
        batch_size=8, flops_per_client_round=2e12,
        checkpoint_mgr=mgr, checkpoint_every=checkpoint_every, seed=seed)
    return orch, params


def test_async_masked_equals_plain_every_commit():
    """The acceptance property: an async run with dropout faults and
    timeout (partial-buffer) commits produces, commit for commit, the
    same aggregation masked as plain — identical commit metadata, equal
    delta norms, final params within 1e-5."""
    o_plain, p0 = make_async(secure=False)
    o_sec, _ = make_async(secure=True)
    p_plain, _ = o_plain.run(p0, num_commits=8)
    p_sec, _ = o_sec.run(p0, num_commits=8)
    assert len(o_plain.logs) == len(o_sec.logs) >= 8
    assert any(l.timeout_commit for l in o_plain.logs), \
        "fixture must exercise partial-buffer timeout commits"
    assert o_plain.lost_to_faults > 0, "fixture must exercise dropouts"
    for lp, ls in zip(o_plain.logs, o_sec.logs):
        assert (lp.commit, lp.n_updates, lp.timeout_commit,
                lp.mean_staleness) == \
               (ls.commit, ls.n_updates, ls.timeout_commit,
                ls.mean_staleness)
        if math.isfinite(lp.delta_norm):
            np.testing.assert_allclose(lp.delta_norm, ls.delta_norm,
                                       rtol=1e-4, atol=1e-6)
    assert all(l.mask_overhead_bytes == 0 for l in o_plain.logs)
    _close(p_plain, p_sec)


def test_async_secure_kill_resume_stays_on_trajectory(tmp_path):
    """Mask state survives kill/--resume: a secure run killed mid-stream
    and restored replays the straight secure run bit-for-bit (commit log
    + params), which in turn matches the plain run to 1e-5."""
    straight, p0 = make_async(secure=True)
    p_straight, _ = straight.run(p0, num_commits=6)

    mgr = AsyncCheckpointManager(str(tmp_path / "ck"))
    killed, _ = make_async(secure=True, mgr=mgr)
    killed.run(p0, num_commits=3)            # terminal snapshot at commit 3

    resumed, _ = make_async(secure=True, mgr=mgr)
    p_mid, ss = mgr.restore_async(resumed, p0)
    assert resumed.version == 3
    p_res, _ = resumed.run(p_mid, num_commits=6, server_state=ss)

    def norm(d):
        # phase_wall is host-side profiling: never trajectory-comparable
        return {k: ("nan" if isinstance(v, float) and math.isnan(v) else v)
                for k, v in d.items() if k != "phase_wall"}

    assert [norm(asdict(l)) for l in resumed.logs] == \
           [norm(asdict(l)) for l in straight.logs]
    _close(p_res, p_straight, tol=1e-7)

    plain, _ = make_async(secure=False)
    p_plain, _ = plain.run(p0, num_commits=6)
    _close(p_res, p_plain)


def test_async_adaptive_alpha_moves_and_is_logged():
    """staleness_exponent='adaptive' runs green end to end; the logged
    alpha starts at the controller's init and then tracks observations."""
    o2, p0 = make_async(secure=False, faults=FaultConfig(),
                        staleness_exponent="adaptive")
    o2.run(p0, num_commits=6)
    alphas = [l.staleness_alpha for l in o2.logs]
    assert alphas[0] == pytest.approx(0.5)      # controller init
    assert len(set(round(a, 6) for a in alphas)) > 1   # it actually adapts


def test_sync_orchestrator_secure_matches_plain():
    """--secure-agg in --mode sync: same fleet/seed, masked vs plain, equal
    params after 3 barrier rounds."""
    def make(secure):
        data = medmnist_like(n=400, seed=SEED)
        parts = partition_dirichlet(data.y, N_CLIENTS, alpha=0.5, seed=SEED)
        fed = FederatedDataset(data, parts, seed=SEED)
        model = CNN(CNN_CFG)
        params = model.init(jax.random.PRNGKey(SEED))
        fleet = make_hybrid_fleet(N_CLIENTS // 2, N_CLIENTS // 2, seed=SEED,
                                  data_sizes=[len(p) for p in parts])
        orch = Orchestrator(
            fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
            fl=FLConfig(num_clients=4, local_steps=1, client_lr=0.05,
                        secure_agg=secure),
            straggler=StragglerPolicy(contention_sigma=0.5),
            batch_size=8, flops_per_client_round=2e12, seed=SEED)
        return orch, params

    o_plain, p0 = make(False)
    o_sec, _ = make(True)
    p_plain, _ = o_plain.run(p0, 3)
    p_sec, _ = o_sec.run(p0, 3)
    _close(p_plain, p_sec)
    assert [l.bytes_up for l in o_plain.logs] == \
           [l.bytes_up for l in o_sec.logs]    # compression off: same wire


def test_pre_secure_era_checkpoint_still_restores(tmp_path):
    """Checkpoints written before the secure-agg/adaptive-alpha fields
    existed (PR 3 format) must still restore into a plain constant-
    exponent orchestrator — the loader defaults the missing keys."""
    import json
    mgr = AsyncCheckpointManager(str(tmp_path / "ck"))
    writer, p0 = make_async(secure=False, mgr=mgr, faults=FaultConfig())
    writer.run(p0, num_commits=2)
    step_dir = mgr.step_dir(writer.version)
    path = step_dir / "async_state.json"
    state = json.loads(path.read_text())
    for k in ("alpha", "staleness_ctrl"):      # forge the PR 3 format
        state.pop(k)
    for k in ("secure_agg", "staleness_exponent"):
        state["config"].pop(k)
    path.write_text(json.dumps(state))

    restored, _ = make_async(secure=False, faults=FaultConfig())
    p_mid, ss = mgr.restore_async(restored, p0)
    assert restored.version == 2
    assert restored._alpha == pytest.approx(0.5)
    restored.run(p_mid, num_commits=4, server_state=ss)
    assert restored.version == 4
