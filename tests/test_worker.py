"""Deployment-shaped worker round-trip: orchestrator writes the global
model, the worker process (the command the scheduler artifacts launch)
trains on its private shard and writes a usable update back."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]


def test_worker_round_trip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree
    from repro.models.cnn import CIFAR_CNN, CNN

    model = CNN(CIFAR_CNN)
    params = model.init(jax.random.PRNGKey(0))
    save_pytree(tmp_path / "global_round_0000.bin",
                jax.tree.map(np.asarray, params))

    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"), JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-m", "repro.worker", "--client-id", "3",
         "--workdir", str(tmp_path), "--once", "--local-steps", "2",
         "--batch-size", "8", "--timeout-s", "120"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]

    upd_path = tmp_path / "update_0000_client_003.bin"
    assert upd_path.exists()
    delta = load_pytree(upd_path, params)
    norms = [float(np.linalg.norm(np.asarray(l)))
             for l in jax.tree.leaves(delta)]
    assert sum(norms) > 0                      # actually trained
    meta = json.loads((tmp_path / "update_0000_client_003.json").read_text())
    assert np.isfinite(meta["loss"]) and meta["data_size"] > 0
    # orchestrator-side application
    new_params = jax.tree.map(lambda p, d: p + np.asarray(d), params, delta)
    jax.tree.map(lambda a: None, new_params)   # structure intact
