"""Hierarchical (two-tier) federation: facilities over modeled WAN links.

Pins the subsystem's contracts:
  * link accounting — ``link_for_site`` fails loudly on unknown sites and
    inter-facility traffic is billed on the ``dcn`` class with the
    ``inter_facility`` direction (the old behaviour silently billed typo'd
    sites at cloud latency and WAN legs at client-uplink cost);
  * a 1-facility hierarchy IS the flat federation (params to 1e-6, same
    round logs) — the degenerate-case equivalence that keeps tier-2 honest;
  * kill/--resume is bit-identical for every (local_mode, inter_mode)
    combination: final params, tier-2 commit logs, WAN ledger and every
    facility's tier-1 logs/ledger all replay exactly;
  * facilities run on scheduler-backed execution (Slurm/K8s adapters)
    exactly like flat orchestrators do.
"""
import math
import shutil
from dataclasses import asdict

import jax
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointManager
from repro.comm.transport import (DCN, GRPC_CLOUD, MPI_HPC, WANTopology,
                                  link_for_site)
from repro.core import AsyncConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.exec import SchedulerBackend
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (HierarchicalOrchestrator, Orchestrator,
                                make_facilities, make_hybrid_fleet)
from repro.sched import HybridAdapter, K8sAdapter, SlurmAdapter

CFG = CNNConfig("tiny-cnn", (28, 28, 1), 9, channels=(4, 8), dense=32)
SEED, N = 11, 8

_MODEL = CNN(CFG)
_DATA = medmnist_like(n=400, seed=SEED)
_PARTS = partition_dirichlet(_DATA.y, N, alpha=0.5, seed=SEED)
_PARAMS0 = _MODEL.init(jax.random.PRNGKey(SEED))
_FL = FLConfig(mode="sync", num_clients=4, local_steps=1, client_lr=0.05)

# the jit'd steps depend only on (model cfg, FLConfig, async cfg) — all
# fixed here — so share them across orchestrator instances: the suite
# compiles each step once instead of once per run
_STEP_CACHE: dict = {}


def _share_steps(hier):
    for fac in hier.facilities:
        if fac.mode == "sync":
            key = ("t1-sync",)
            if key in _STEP_CACHE:
                fac.orch._round_step = _STEP_CACHE[key]
            else:
                _STEP_CACHE[key] = fac.orch._round_step
        else:
            key = ("t1-async", fac.orch.async_cfg.buffer_size)
            if key in _STEP_CACHE:
                fac.orch._client_update, fac.orch._commit_step = _STEP_CACHE[key]
            else:
                _STEP_CACHE[key] = (fac.orch._client_update,
                                    fac.orch._commit_step)
    key = ("t2", hier.async_cfg.buffer_size)
    if key in _STEP_CACHE:
        hier._commit_step = _STEP_CACHE[key]
    else:
        _STEP_CACHE[key] = hier._commit_step
    return hier


def _fleet():
    return make_hybrid_fleet(N // 2, N - N // 2, seed=SEED,
                             data_sizes=[len(p) for p in _PARTS])


def _fed():
    return FederatedDataset(_DATA, _PARTS, seed=SEED)


def _hier(n_fac=2, local_mode="sync", inter_mode="sync", local_rounds=2,
          mgr=None, every=0, backend_factory=None, wan=None):
    facs = make_facilities(
        n_fac, _fleet(), _fed(), _MODEL.loss_fn, _FL, local_mode=local_mode,
        async_cfg=AsyncConfig(buffer_size=2, max_concurrency=3),
        local_rounds=local_rounds, backend_factory=backend_factory,
        seed=SEED, orch_kw=dict(batch_size=8, flops_per_client_round=2e12))
    return _share_steps(HierarchicalOrchestrator(
        facs, _FL, inter_mode=inter_mode,
        async_cfg=AsyncConfig(buffer_size=1) if inter_mode == "async" else None,
        wan=wan, checkpoint_mgr=mgr, checkpoint_every=every, seed=SEED))


def _norm(o):
    if isinstance(o, dict):
        # phase_wall is host-side profiling: never trajectory-comparable
        return {k: _norm(v) for k, v in o.items() if k != "phase_wall"}
    if isinstance(o, (list, tuple)):
        return [_norm(x) for x in o]
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, float) and math.isnan(o):
        return "nan"
    if isinstance(o, np.floating):
        return float(o)
    return o


# ------------------------------------------------------------ link accounting
def test_link_for_site_known_sites():
    assert link_for_site("hpc") is MPI_HPC
    assert link_for_site("cloud") is GRPC_CLOUD


def test_link_for_site_unknown_site_fails_loudly():
    with pytest.raises(KeyError, match="unknown site 'cluod'"):
        link_for_site("cluod")


def test_wan_topology_pair_override_and_jitter():
    wan = WANTopology()
    assert wan.link("a", "b") is DCN
    wan.set_pair("a", "b", bandwidth_GBps=0.5, latency_s=0.1)
    lk = wan.link("b", "a")            # symmetric
    assert lk.name == "dcn"            # overrides keep the dcn link class
    assert lk.bandwidth_GBps == 0.5 and lk.latency_s == 0.1
    assert wan.link("a", "c") is DCN   # other pairs untouched
    t0 = wan.transfer_time("a", "b", 1e9)
    assert t0 == pytest.approx(0.1 + 1e9 / 0.5e9)
    jittery = WANTopology(jitter_s=0.5)
    rng = np.random.default_rng(0)
    draws = {jittery.transfer_time("a", "b", 1e6, rng=rng) for _ in range(4)}
    assert len(draws) == 4             # exponential tail varies per draw
    base = DCN.transfer_time(1e6)
    assert all(d > base for d in draws)


# ----------------------------------------------------------------- two tiers
def test_two_facility_sync_over_dcn():
    hier = _hier(local_mode="sync", inter_mode="sync")
    hier.run(_PARAMS0, 3)
    assert hier.version == 3
    assert hier.comm.records, "tier-2 must log WAN transfers"
    # every inter-facility transfer is billed on the dcn class, and the
    # tier-2 ledger holds ONLY inter-facility traffic (client up/down stays
    # in the facility ledgers)
    assert all(r.link == "dcn" for r in hier.comm.records)
    assert all(r.direction == "inter_facility" for r in hier.comm.records)
    assert hier.inter_facility_bytes > 0
    assert hier.logs[-1].inter_facility_bytes > 0
    # tier-1 client traffic stays on site links inside the facilities
    for fac in hier.facilities:
        assert fac.orch.comm.records
        assert all(r.link in ("mpi_hpc", "grpc_cloud")
                   for r in fac.orch.comm.records)


def test_two_facility_async_commits_with_staleness():
    hier = _hier(local_mode="async", inter_mode="async")
    hier.run(_PARAMS0, 4)
    assert hier.version == 4
    assert hier.clock > 0.0
    assert all(not math.isnan(l.mean_staleness) for l in hier.logs)


def test_one_facility_hierarchy_is_flat():
    hier = _hier(n_fac=1, local_mode="sync", inter_mode="sync",
                 local_rounds=3)
    ph, _ = hier.run(_PARAMS0, 1)

    flat = Orchestrator(fleet=_fleet(), fed_data=_fed(),
                        loss_fn=_MODEL.loss_fn, fl=_FL, batch_size=8,
                        flops_per_client_round=2e12, seed=SEED)
    flat._round_step = _STEP_CACHE[("t1-sync",)]
    pf, _ = flat.run(_PARAMS0, 3)
    err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
              for a, b in zip(jax.tree.leaves(ph), jax.tree.leaves(pf)))
    assert err < 1e-6
    flog = hier.facilities[0].orch.logs
    assert len(flog) == len(flat.logs)
    for a, b in zip(flog, flat.logs):
        assert a.selected == b.selected
        assert a.participated == b.participated
        assert abs(a.client_loss - b.client_loss) < 1e-6


# ------------------------------------------------------------- kill / resume
@pytest.mark.parametrize("local_mode,inter_mode", [
    ("sync", "sync"), ("async", "async"), ("async", "sync"),
    ("sync", "async")])
def test_hier_resume_bit_identical(tmp_path, local_mode, inter_mode):
    ck = str(tmp_path / f"hier-ck-{local_mode}-{inter_mode}")
    shutil.rmtree(ck, ignore_errors=True)
    straight = _hier(local_mode=local_mode, inter_mode=inter_mode)
    ps, _ = straight.run(_PARAMS0, 4)

    killed = _hier(local_mode=local_mode, inter_mode=inter_mode,
                   mgr=AsyncCheckpointManager(ck), every=1)
    killed.run(_PARAMS0, 2)

    resumed = _hier(local_mode=local_mode, inter_mode=inter_mode,
                    mgr=AsyncCheckpointManager(ck), every=1)
    params, server_state = resumed.checkpoint_mgr.restore_hier(
        resumed, _PARAMS0)
    assert resumed.version == 2
    pr, _ = resumed.run(params, 4, server_state=server_state)

    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(ps), jax.tree.leaves(pr)))
    assert _norm([asdict(l) for l in straight.logs]) == \
        _norm([asdict(l) for l in resumed.logs])
    assert _norm([asdict(r) for r in straight.comm.records]) == \
        _norm([asdict(r) for r in resumed.comm.records])
    for sf, rf in zip(straight.facilities, resumed.facilities):
        assert _norm([asdict(l) for l in sf.orch.logs]) == \
            _norm([asdict(l) for l in rf.orch.logs])
        assert _norm([asdict(r) for r in sf.orch.comm.records]) == \
            _norm([asdict(r) for r in rf.orch.comm.records])


# ------------------------------------------------------- scheduler facilities
def test_facilities_on_scheduler_backend():
    def backend_factory(f):
        return SchedulerBackend(HybridAdapter(
            slurm=SlurmAdapter(total_nodes=8, seed=f),
            k8s=K8sAdapter(initial_nodes=8, max_nodes=8, seed=f + 1)))

    hier = _hier(local_mode="sync", inter_mode="async",
                 backend_factory=backend_factory)
    hier.run(_PARAMS0, 3)
    assert hier.version == 3
    assert all(r.direction == "inter_facility" and r.link == "dcn"
               for r in hier.comm.records)
