import os

# Tests run on the single real CPU device; only launch/dryrun.py (executed as
# a subprocess) uses the 512-placeholder-device XLA flag.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
