"""CI fast-lane smoke: fused commit under an active 2-device CPU mesh.

Run directly (NOT a pytest file — the XLA device count must be forced
before jax initialises, so this runs as its own process):

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python tests/mesh_smoke.py

Asserts the PR 10 gate-lift acceptance on the cheapest possible case:
with an active ("data",) mesh, ``UpdatePipeline.fused`` stays True and the
fused (shard_mapped Pallas) commit matches the unfused stage stack <= 1e-5
for one sync sequential round AND one async buffered secure commit.  The
exhaustive version (four regimes, 1x2 + 2x2 meshes, real archs) lives in
tests/test_mesh_small.py on the slow lane.
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.core import (AsyncConfig, CompressionConfig,       # noqa: E402
                        FLConfig, build_buffer_commit_step,
                        build_client_update_step, build_fl_round_step,
                        build_update_pipeline)
from repro.models import build_model, sharding as sh          # noqa: E402
from repro.optim import (get_client_optimizer,                # noqa: E402
                         get_server_optimizer)

C, H, b, S = 4, 1, 2, 16
DET = dict(quantize_bits=8, topk_frac=0.1, stochastic_rounding=False)


def tree_diff(t1, t2):
    return max(float(jnp.abs(a - b2).max())
               for a, b2 in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)))


def main():
    assert len(jax.devices()) >= 2, (
        "needs XLA_FLAGS=--xla_force_host_platform_device_count=2")
    cfg = get_config("paper-charlm").replace(n_layers=1, d_model=64,
                                             d_ff=128, n_heads=2, kv_heads=2)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (C, H, b, S + 1), 0,
                              cfg.vocab, jnp.int32)
    batches = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
    copt, sopt = get_client_optimizer("sgd"), get_server_optimizer("fedavg")
    mesh = jax.make_mesh((2,), ("data",))

    with sh.use_mesh(mesh), mesh:
        assert build_update_pipeline(FLConfig()).fused, (
            "gate-lift regression: fused off under an active mesh")

        # sync sequential round, fused vs unfused
        sync = {}
        for use_fused in (True, False):
            fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                          client_exec="sequential",
                          compression=CompressionConfig(use_fused=use_fused,
                                                        **DET))
            step = jax.jit(build_fl_round_step(m.loss_fn, copt, sopt, fl))
            sync[use_fused] = step(params, (), batches, jnp.ones((C,)),
                                   jnp.ones((C,)), jax.random.PRNGKey(2))[0]
        d_sync = tree_diff(sync[True], sync[False])
        assert d_sync <= 1e-5, f"sync fused/unfused diverged: {d_sync}"

        # async buffered secure commit, fused vs unfused
        rng = jax.random.PRNGKey(4)
        acfg = AsyncConfig(buffer_size=C)
        asy = {}
        for use_fused in (True, False):
            fl = FLConfig(mode="async", num_clients=C, local_steps=H,
                          client_lr=0.1, secure_agg=True,
                          compression=CompressionConfig(use_fused=use_fused,
                                                        **DET))
            client_step = jax.jit(build_client_update_step(m.loss_fn, copt,
                                                           fl))
            rngs = jax.random.split(rng, C)
            deltas = [client_step(params,
                                  jax.tree.map(lambda x: x[c], batches),
                                  rngs[c])[0] for c in range(C)]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
            commit = jax.jit(build_buffer_commit_step(sopt, fl, acfg))
            asy[use_fused] = commit(
                params, (), stacked, jnp.ones((C,)),
                jnp.asarray([0.0, 1.0, 3.0, 2.0]), jnp.zeros(C),
                jnp.ones((C,)), jnp.arange(C, dtype=jnp.int32),
                jnp.float32(0.5), rng)[0]
        d_async = tree_diff(asy[True], asy[False])
        assert d_async <= 1e-5, f"async fused/unfused diverged: {d_async}"

    print(f"mesh smoke OK: devices={len(jax.devices())} "
          f"sync_diff={d_sync:.2e} async_diff={d_async:.2e} (fused stayed on)")


if __name__ == "__main__":
    main()
