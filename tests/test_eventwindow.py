"""Unit pins for the event-window engine's primitives.

The engine's bit-identity claim rests on three exactness contracts that
are properties of numpy/jax, not of our code — so each is pinned here
directly, independent of any orchestrator:

* ``BlockedGenerator``: a block draw of n equals n sequential scalar
  draws AND leaves the same bit-generator state; partial-block syncs
  recover the sequential state exactly; mixed-kind interleaves and
  state-dependent draws (choice/integers) match a raw Generator.
* ``_KeyBlock``: the scanned key chain equals sequential
  ``jax.random.split`` calls bitwise.
* ``PendingStore``: ordering, iteration and payload round-trips match
  the legacy (t, seq, upd) heap, including (t) ties broken by seq.
"""
import heapq

import jax
import numpy as np
import pytest

from repro.orchestrator.eventwindow import (BlockedGenerator, PendingStore,
                                            _KeyBlock)


def _state(g):
    return g.bit_generator.state


# ------------------------------------------------------ BlockedGenerator
@pytest.mark.parametrize("kind,args", [
    ("random", ()),
    ("uniform", (0.05, 0.95)),
    ("lognormal", (0.0, 0.5)),
])
@pytest.mark.parametrize("consumed", [0, 1, 5, 8])
def test_block_equals_sequential_and_state_syncs(kind, args, consumed):
    """n scalar draws == prefix of a block of >= n, and a partially
    consumed block re-syncs to the exact sequential state."""
    seq = np.random.default_rng(42)
    blk = BlockedGenerator(np.random.default_rng(42), window=8)
    draw_seq = getattr(seq, kind)
    draw_blk = getattr(blk, kind)
    vals = [(draw_seq(*args), draw_blk(*args)) for _ in range(consumed)]
    for a, b in vals:
        assert float(a) == float(b)
    assert _state(seq) == _state(blk)        # sync happens via the property
    # and the stream continues identically after the sync
    assert float(draw_seq(*args)) == float(draw_blk(*args))


def test_mixed_kind_interleave_matches_raw_generator():
    seq = np.random.default_rng(7)
    blk = BlockedGenerator(np.random.default_rng(7), window=4)
    script = ["random", "lognormal", "lognormal", "uniform", "random",
              "uniform", "uniform", "lognormal", "random", "random"]
    args = {"random": (), "lognormal": (0.0, 0.3), "uniform": (0.1, 0.9)}
    for kind in script:
        assert float(getattr(seq, kind)(*args[kind])) == \
            float(getattr(blk, kind)(*args[kind]))
    assert _state(seq) == _state(blk)


def test_state_dependent_draws_sync_first():
    """choice/integers aren't blocked: they must see the sequential state
    mid-block, exactly like a raw generator at the same point."""
    seq = np.random.default_rng(3)
    blk = BlockedGenerator(np.random.default_rng(3), window=16)
    for _ in range(5):
        assert seq.random() == blk.random()   # leaves an 11-deep live block
    assert int(seq.integers(1000)) == int(blk.integers(1000))
    assert int(seq.choice(50)) == int(blk.choice(50))
    assert seq.lognormal(0.0, 0.5) == blk.lognormal(0.0, 0.5)
    assert _state(seq) == _state(blk)


def test_array_requests_and_reserve():
    seq = np.random.default_rng(9)
    blk = BlockedGenerator(np.random.default_rng(9), window=4)
    blk.reserve(12)                          # next refill must cover 12
    a = blk.random(size=10)                  # served from one 12-block
    b = seq.random(size=10)
    assert np.array_equal(a, b)
    assert blk.random() == seq.random()      # two left in the block
    assert blk.random() == seq.random()
    assert blk.random() == seq.random()      # forces a refill
    assert _state(seq) == _state(blk)


def test_checkpoint_state_set_through_wrapper():
    """The checkpoint loader assigns bit_generator.state through the
    wrapper property — the restored stream must be exact."""
    donor = np.random.default_rng(123)
    donor.random(size=17)                    # advance to an arbitrary state
    snap = donor.bit_generator.state

    blk = BlockedGenerator(np.random.default_rng(0), window=8)
    blk.random()                             # leave a live block behind
    blk.bit_generator.state = snap
    ref = np.random.default_rng(123)
    ref.random(size=17)
    assert [ref.random() for _ in range(5)] == \
        [blk.random() for _ in range(5)]


# ------------------------------------------------------------- _KeyBlock
def test_key_block_matches_sequential_splits():
    key = jax.random.PRNGKey(7)
    kb = _KeyBlock(window=5)
    chain = key
    for i in range(13):                      # crosses two refills
        sub_kb, chain_kb = kb.next(chain if i == 0 else chain_kb)
        chain, sub = jax.random.split(chain)
        assert np.array_equal(np.asarray(sub), np.asarray(sub_kb)), i
        assert np.array_equal(np.asarray(chain), np.asarray(chain_kb)), i


def test_key_block_reset_after_chain_rewrite():
    kb = _KeyBlock(window=4)
    k1 = jax.random.PRNGKey(1)
    kb.next(k1)
    kb.reset()                               # simulate a checkpoint restore
    k2 = jax.random.PRNGKey(2)
    sub, chain = kb.next(k2)
    ref_chain, ref_sub = jax.random.split(k2)
    assert np.array_equal(np.asarray(sub), np.asarray(ref_sub))
    assert np.array_equal(np.asarray(chain), np.asarray(ref_chain))


# ---------------------------------------------------------- PendingStore
class _Upd:
    def __init__(self, seq, cid=0, version=0, fault=""):
        self.seq, self.cid = seq, cid
        self.dispatch_version, self.fault = version, fault


def test_pending_store_orders_like_legacy_heap():
    rng = np.random.default_rng(0)
    store = PendingStore()
    legacy = []
    for seq in range(300):
        t = float(rng.choice([1.0, 2.5, 2.5, 7.0]))  # force (t) ties
        upd = _Upd(seq, cid=seq % 9, version=seq % 4)
        store.push(t, seq, upd)
        heapq.heappush(legacy, (t, seq, upd))
        if seq % 3 == 2:
            assert store.pop() == heapq.heappop(legacy)
    while legacy:
        assert store.pop() == heapq.heappop(legacy)
    assert len(store) == 0


def test_pending_store_iteration_round_trips():
    """iter() yields (t, seq, upd) tuples the serializer/loader consume;
    a store rebuilt from them replays identically."""
    store = PendingStore()
    for seq, t in enumerate([3.0, 1.0, 2.0, 1.0]):
        store.push(t, seq, _Upd(seq, cid=10 + seq))
    rebuilt = PendingStore(list(store))
    assert len(rebuilt) == 4
    a = [store.pop() for _ in range(4)]
    b = [rebuilt.pop() for _ in range(4)]
    assert [(t, s) for t, s, _ in a] == [(t, s) for t, s, _ in b]
    assert [u.cid for _, _, u in a] == [u.cid for _, _, u in b]


def test_pending_store_rows_and_compaction():
    store = PendingStore()
    # push/pop far beyond the 64-row initial capacity with a live set that
    # stays small: exercises both grow and dead-row compaction
    for seq in range(1000):
        store.push(float(seq), seq, _Upd(seq, cid=seq, version=seq // 10,
                                         fault="preempt" if seq % 7 else ""))
        if seq >= 20:
            store.pop()
    assert len(store) == 20
    rows = store.live
    assert sorted(rows["seq"].tolist()) == list(range(980, 1000))
    assert np.array_equal(np.sort(rows["t"]),
                          np.arange(980.0, 1000.0))
    stal = store.staleness(200)
    assert np.array_equal(np.sort(stal), np.sort(200 - rows["version"]))
    assert store.min_time() == 980.0
