"""Mega-fleet scale smoke (CI fast lane, ``-m scale``): a 10k-client async
simulation must complete a fixed commit budget inside a wall-clock budget,
with memory-proportional-to-participants laziness actually holding — plus a
1e6-client run on the vectorized event-window engine, the rung the batched
per-event heap could not reach.

The budgets are deliberately loose (the runs take seconds locally including
jit compiles) — the tests exist to catch accidental O(population) work
creeping into dispatch, checkpointing, or dataset sampling, which shows up
as a 10-100x blowup, not a few percent."""
import time

import jax
import numpy as np
import pytest

from repro.core import AsyncConfig, FLConfig
from repro.data import (VirtualFederatedDataset, medmnist_like,
                        partition_dirichlet)
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (BatchedAsyncOrchestrator,
                                EventWindowOrchestrator, FaultConfig,
                                StragglerPolicy, make_mega_fleet)

WALL_BUDGET_S = 90.0
N_CLIENTS = 10_000
N_COMMITS = 5
BUFFER_K = 32

CFG = CNNConfig("mega-mlp", (28, 28, 1), 9, channels=(), dense=64)


@pytest.mark.scale
def test_10k_client_async_sim_under_wall_budget():
    data = medmnist_like(n=600, seed=0)
    parts = partition_dirichlet(data.y, 8, alpha=0.5, seed=0)
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    orch = BatchedAsyncOrchestrator(
        fleet=make_mega_fleet(N_CLIENTS, seed=3),
        fed_data=VirtualFederatedDataset(data, parts, seed=0,
                                         n_virtual=N_CLIENTS),
        loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=N_CLIENTS, local_steps=2,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=BUFFER_K, max_concurrency=128,
                              max_staleness=100),
        faults=FaultConfig(dropout_prob=0.02, spot_preempt_prob=0.05,
                           recovery_policy="discard"),
        straggler=StragglerPolicy(contention_sigma=0.5),
        batch_size=8, flops_per_client_round=1e12, seed=7)
    new_params, _ = orch.run(params, N_COMMITS)
    wall = time.perf_counter() - t0

    assert wall < WALL_BUDGET_S, f"10k-client sim took {wall:.1f}s"
    assert orch.version == N_COMMITS
    assert orch.updates_applied == N_COMMITS * BUFFER_K
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(new_params))), \
        "params never moved"
    # laziness: only participants were ever materialized
    assert len(orch.fleet.live) < N_CLIENTS // 10
    assert len(orch.fed_data._rngs) < N_CLIENTS // 10
    assert len(orch.fleet.live) >= len(orch.events_processed) and \
        len(orch.events_processed) > 0


@pytest.mark.scale
def test_1e6_client_window_engine_under_wall_budget():
    """The event-window engine runs a MILLION-client fleet: construction is
    O(#cohorts), dispatch/commit work scales with participants, and the
    windowed RNG blocks + one-fetch-per-commit keep host syncs flat."""
    n_clients, n_commits, buffer_k = 1_000_000, 3, 32
    data = medmnist_like(n=600, seed=0)
    parts = partition_dirichlet(data.y, 8, alpha=0.5, seed=0)
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    orch = EventWindowOrchestrator(
        fleet=make_mega_fleet(n_clients, seed=3),
        fed_data=VirtualFederatedDataset(data, parts, seed=0,
                                         n_virtual=n_clients),
        loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=n_clients, local_steps=2,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=buffer_k, max_concurrency=128,
                              max_staleness=100),
        straggler=StragglerPolicy(contention_sigma=0.5),
        batch_size=8, flops_per_client_round=1e12, seed=7)
    new_params, _ = orch.run(params, n_commits)
    wall = time.perf_counter() - t0

    assert wall < WALL_BUDGET_S, f"1e6-client sim took {wall:.1f}s"
    assert orch.version == n_commits
    assert orch.updates_applied == n_commits * buffer_k
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(new_params))), \
        "params never moved"
    # laziness: only participants were ever materialized, out of a million
    assert len(orch.fleet.live) < 2_000
    assert len(orch.fed_data._rngs) < 2_000
    # one bundled device fetch per commit window, not per update
    assert all(l.phase_wall["host_syncs"] > 0 for l in orch.logs)
    total_syncs = sum(l.phase_wall["host_syncs"] for l in orch.logs)
    assert total_syncs < 30 * n_commits


@pytest.mark.scale
def test_100k_fleet_construction_is_o_cohorts():
    t0 = time.perf_counter()
    fleet = make_mega_fleet(100_000, seed=0)
    assert len(fleet) == 100_000
    assert fleet.cohort_of(0) == 0 and fleet.cohort_of(99_999) == \
        len(fleet.cohorts) - 1
    c = fleet[54_321]
    assert c.cid == 54_321 and len(fleet.live) == 1
    assert time.perf_counter() - t0 < 5.0
