"""Fault injection in the async event stream: spot preemption and site
partitions must actually change event timing/ordering (not just zero a mask
at commit time), and the recovery_policy knob must produce its three
distinct behaviours with recovery-time accounting in the CommitLog."""
import jax
import numpy as np

from repro.core import AsyncConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (AsyncOrchestrator, FaultConfig,
                                StragglerPolicy, make_hybrid_fleet)

CFG = CNNConfig("tiny-cnn", (28, 28, 1), 9, channels=(4, 8), dense=32)
SEED, N = 3, 8

_STEP_CACHE: dict = {}


def make_orch(faults=None, seed=SEED, local_steps=2):
    data = medmnist_like(n=400, seed=seed)
    parts = partition_dirichlet(data.y, N, alpha=0.5, seed=seed)
    fed = FederatedDataset(data, parts, seed=seed)
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(seed))
    fleet = make_hybrid_fleet(N // 2, N - N // 2, seed=seed,
                              data_sizes=[len(p) for p in parts])
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=N, local_steps=local_steps,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=2, max_concurrency=6,
                              max_staleness=50),
        straggler=StragglerPolicy(contention_sigma=0.5),
        faults=faults or FaultConfig(),
        batch_size=8, flops_per_client_round=2e12, seed=seed)
    key = local_steps
    if key in _STEP_CACHE:
        orch._client_update, orch._commit_step = _STEP_CACHE[key]
    else:
        _STEP_CACHE[key] = (orch._client_update, orch._commit_step)
    return orch, params


def test_spot_preemption_alters_event_stream():
    clean, params = make_orch()
    clean.run(params, num_commits=5)
    faulted, params = make_orch(
        faults=FaultConfig(spot_preempt_prob=0.6, recovery_policy="discard"))
    assert any(c.profile.spot for c in faulted.fleet)
    faulted.run(params, num_commits=5)
    # preemptions land as typed events at their strike time, so the event
    # stream itself diverges from the clean run under the same seed
    assert faulted.events_processed != clean.events_processed
    assert any(e[4] == "preempt" for e in faulted.events_processed)
    assert faulted.lost_to_faults > 0            # discard: the work is gone
    assert faulted.recovered_updates == 0


def test_partition_alters_event_stream_and_recovers():
    clean, params = make_orch()
    clean.run(params, num_commits=6)
    faulted, params = make_orch(
        faults=FaultConfig(partition_prob=1.0, partition_len=2,
                           recovery_policy="resume"))
    faulted.run(params, num_commits=6)
    assert faulted.events_processed != clean.events_processed
    assert any(e[4] == "partition" for e in faulted.events_processed)
    # resume policy: partitioned clients re-enqueue their remaining work and
    # their recovered updates eventually commit, with recovery-time accounting
    assert faulted.recovered_updates > 0
    assert faulted.recovery_time_total > 0
    assert any(l.n_recovered > 0 and l.recovery_time_s > 0
               for l in faulted.logs)


def test_recovery_policies_are_distinct():
    def run(policy):
        orch, params = make_orch(
            faults=FaultConfig(spot_preempt_prob=0.7, recovery_policy=policy,
                               max_retries=3))
        p, _ = orch.run(params, num_commits=5)
        return orch, p

    discard, p_discard = run("discard")
    resume, p_resume = run("resume")
    restart, p_restart = run("restart")
    assert discard.recovered_updates == 0 and discard.lost_to_faults > 0
    assert resume.recovered_updates > 0
    assert restart.recovered_updates > 0
    # recovery time measures delay vs. the landing attempt's fault-free
    # duration — never negative, even when a restart retry draws a short one
    for orch in (resume, restart):
        assert orch.recovery_time_total >= 0
        assert all(l.recovery_time_s >= 0 for l in orch.logs)
    # restart re-fetches the model on every retry; resume works from the
    # local step checkpoint and never pays a second downlink
    downs = lambda o: sum(r.direction == "down" for r in o.comm.records)
    assert downs(restart) > downs(resume)
    leaves = lambda p: np.concatenate([np.ravel(x) for x in jax.tree.leaves(p)])
    assert not np.allclose(leaves(p_resume), leaves(p_discard))


def test_plain_dropout_is_never_recovered():
    orch, params = make_orch(
        faults=FaultConfig(dropout_prob=0.5, recovery_policy="resume"))
    orch.run(params, num_commits=5)
    assert any(e[4] == "dropout" for e in orch.events_processed)
    assert orch.lost_to_faults > 0
    assert orch.recovered_updates == 0


def test_faulted_event_stream_deterministic_under_seed():
    runs = []
    for _ in range(2):
        orch, params = make_orch(
            faults=FaultConfig(spot_preempt_prob=0.5, partition_prob=0.3,
                               recovery_policy="resume"))
        orch.run(params, num_commits=6)
        runs.append(orch.events_processed)
    assert runs[0] == runs[1] and len(runs[0]) > 0
