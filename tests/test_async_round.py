"""Async (FedBuff-style) aggregation semantics: staleness weighting vs a
NumPy reference, buffer-commit math, and the acceptance-criterion
equivalence — async with zero staleness matches the sync round step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AdaptiveStalenessController, AsyncConfig,
                        CompressionConfig, FLConfig,
                        build_buffer_commit_step, build_client_update_step,
                        build_fl_round_step, staleness_weights)
from repro.models import build_model
from repro.optim import get_client_optimizer, get_server_optimizer

C, H, b, S = 4, 2, 2, 16


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-charlm").replace(n_layers=2, d_model=64, d_ff=128,
                                             n_heads=2, kv_heads=2)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (C, H, b, S + 1), 0,
                              cfg.vocab, jnp.int32)
    batches = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
    return m, params, batches


# ------------------------------------------------------------ staleness math
def test_staleness_weights_match_numpy_reference():
    s = np.array([0, 1, 2, 5, 20], np.float32)
    for a in (0.0, 0.5, 1.0, 2.0):
        ref = 1.0 / (1.0 + s) ** a
        got = np.asarray(staleness_weights(jnp.asarray(s), a))
        np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_staleness_weights_monotone_and_fresh_is_one():
    s = jnp.arange(0, 30, dtype=jnp.float32)
    w = np.asarray(staleness_weights(s, 0.7))
    assert w[0] == pytest.approx(1.0)
    assert (np.diff(w) < 0).all()          # strictly decreasing in staleness
    assert (w > 0).all()                   # discounted, never discarded


def test_zero_exponent_disables_discount():
    s = jnp.asarray([0.0, 3.0, 17.0])
    np.testing.assert_allclose(np.asarray(staleness_weights(s, 0.0)),
                               np.ones(3))


# ------------------------------------------------------------- commit step
def _commit(fl, acfg, params, deltas, weights, staleness, mask, rng=None,
            losses=None, exponent=None):
    sopt = get_server_optimizer("fedavg")
    step = jax.jit(build_buffer_commit_step(sopt, fl, acfg))
    if losses is None:
        losses = jnp.zeros_like(weights)
    K = weights.shape[0]
    if exponent is None:
        exponent = acfg.initial_exponent()
    return step(params, sopt.init(params), deltas, weights, staleness,
                losses, mask, jnp.arange(K, dtype=jnp.int32),
                jnp.float32(exponent),
                rng if rng is not None else jax.random.PRNGKey(0))


def test_commit_matches_numpy_weighted_mean():
    """Commit over a toy buffer == NumPy staleness-discounted mean,
    normalised by the UN-discounted weight mass (FedBuff step shrinkage)."""
    K, a = 4, 0.5
    rng = np.random.default_rng(0)
    d = rng.normal(size=(K, 3, 5)).astype(np.float32)
    w = np.array([2.0, 1.0, 3.0, 1.5], np.float32)
    s = np.array([0, 2, 1, 5], np.float32)
    params = {"x": jnp.zeros((3, 5), jnp.float32)}
    fl = FLConfig(mode="async")
    acfg = AsyncConfig(buffer_size=K, staleness_exponent=a)
    new_p, _, metrics = _commit(
        fl, acfg, params, {"x": jnp.asarray(d)}, jnp.asarray(w),
        jnp.asarray(s), jnp.ones(K))
    w_eff = w / (1.0 + s) ** a
    ref = (d * w_eff[:, None, None]).sum(0) / w.sum()
    np.testing.assert_allclose(np.asarray(new_p["x"]), ref, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(float(metrics["n_updates"]), K)


def test_uniformly_stale_buffer_takes_shrunken_step():
    """The discount must shrink the ABSOLUTE step, not cancel in the mean:
    a buffer where every update has staleness s steps 1/(1+s)^a as far as
    a fresh one."""
    K, a, s = 3, 1.0, 4.0
    params = {"x": jnp.zeros((4,), jnp.float32)}
    d = {"x": jnp.ones((K, 4), jnp.float32)}
    fl, acfg = FLConfig(mode="async"), AsyncConfig(buffer_size=K,
                                                   staleness_exponent=a)
    p_fresh, _, _ = _commit(fl, acfg, params, d, jnp.ones(K), jnp.zeros(K),
                            jnp.ones(K))
    p_stale, _, _ = _commit(fl, acfg, params, d, jnp.ones(K),
                            jnp.full(K, s), jnp.ones(K))
    np.testing.assert_allclose(np.asarray(p_fresh["x"]), 1.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p_stale["x"]), 1.0 / (1.0 + s),
                               rtol=1e-5)


def test_commit_padding_slots_never_contribute():
    """mask-0 padding (timeout commits) is invisible to the aggregate."""
    K = 4
    params = {"x": jnp.zeros((8,), jnp.float32)}
    d_live = np.random.default_rng(1).normal(size=(2, 8)).astype(np.float32)
    fl, acfg = FLConfig(mode="async"), AsyncConfig(buffer_size=K)
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    wts = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    stal = jnp.zeros(K)
    pad_zero = np.concatenate([d_live, np.zeros((2, 8), np.float32)])
    pad_poison = np.concatenate([d_live, np.full((2, 8), 1e6, np.float32)])
    p1, _, _ = _commit(fl, acfg, params, {"x": jnp.asarray(pad_zero)},
                       wts, stal, mask)
    p2, _, _ = _commit(fl, acfg, params, {"x": jnp.asarray(pad_poison)},
                       wts, stal, mask)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6)


def test_weighted_mode_prefers_low_loss_updates():
    """aggregation='weighted' uses buffered client losses like the sync
    round: a low-loss client's delta outweighs a high-loss one."""
    K = 2
    params = {"x": jnp.zeros((4,), jnp.float32)}
    d = jnp.asarray([[1.0] * 4, [-1.0] * 4], jnp.float32)
    fl = FLConfig(mode="async", aggregation="weighted")
    acfg = AsyncConfig(buffer_size=K, staleness_exponent=0.0)
    p, _, _ = _commit(fl, acfg, params, {"x": d}, jnp.ones(K), jnp.zeros(K),
                      jnp.ones(K), losses=jnp.asarray([0.0, 9.0]))
    # w = [1/(1+0), 1/(1+9)] -> (1 - 0.1) / 1.1
    np.testing.assert_allclose(np.asarray(p["x"]), 0.9 / 1.1, rtol=1e-5)


def test_trimmed_mean_rejected_at_build_time():
    """Robust trimming over a padded staleness buffer is undefined; the
    build must fail loudly rather than silently degrade to a mean."""
    with pytest.raises(ValueError, match="trimmed_mean"):
        build_buffer_commit_step(get_server_optimizer("fedavg"),
                                 FLConfig(mode="async",
                                          aggregation="trimmed_mean"),
                                 AsyncConfig(buffer_size=2))


def test_stale_update_downweighted_in_aggregate():
    """A very stale delta moves the aggregate less than a fresh one."""
    K = 2
    params = {"x": jnp.zeros((4,), jnp.float32)}
    d = jnp.asarray([[1.0, 1.0, 1.0, 1.0], [-1.0, -1.0, -1.0, -1.0]],
                    jnp.float32)
    fl = FLConfig(mode="async")
    acfg = AsyncConfig(buffer_size=K, staleness_exponent=1.0)
    # client 1 (the -1 delta) is 9 commits stale -> weight 1/10; the
    # denominator is the raw weight mass (2), so the step also shrinks
    p, _, _ = _commit(fl, acfg, params, {"x": d}, jnp.ones(K),
                      jnp.asarray([0.0, 9.0]), jnp.ones(K))
    out = np.asarray(p["x"])
    assert (out > 0).all()                      # fresh +1 client dominates
    np.testing.assert_allclose(out, (1.0 - 0.1) / 2.0, rtol=1e-5)


# ----------------------------------------------- sync/async equivalence
def test_zero_staleness_commit_equals_sync_round(setup):
    """Acceptance criterion: deltas computed per-client via the async client
    step and committed with zero staleness reproduce the sync round step's
    new params to <= 1e-5."""
    m, params, batches = setup
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1)
    copt, sopt = get_client_optimizer("sgd"), get_server_optimizer("fedavg")

    sync_step = jax.jit(build_fl_round_step(m.loss_fn, copt, sopt, fl))
    weights = jnp.ones((C,))
    mask = jnp.ones((C,))
    rng = jax.random.PRNGKey(2)
    p_sync, _, _ = sync_step(params, (), batches, weights, mask, rng)

    # async path: per-client updates with the SAME per-client rngs the sync
    # vmap used, then one zero-staleness buffer commit of all C deltas
    client_step = jax.jit(build_client_update_step(m.loss_fn, copt, fl))
    rngs = jax.random.split(rng, C)
    deltas, _losses = [], []
    for c in range(C):
        d, l = client_step(params, jax.tree.map(lambda x: x[c], batches),
                           rngs[c])
        deltas.append(d)
        _losses.append(l)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
    commit = jax.jit(build_buffer_commit_step(
        sopt, fl, AsyncConfig(buffer_size=C, staleness_exponent=0.5)))
    p_async, _, _ = commit(params, (), stacked, weights, jnp.zeros(C),
                           jnp.zeros(C), mask, jnp.arange(C, dtype=jnp.int32),
                           jnp.float32(0.5), rng)
    for a, b_ in zip(jax.tree.leaves(p_sync), jax.tree.leaves(p_async)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_commit_applies_compression_pipeline(setup):
    """The buffered path compresses what crosses the wire, like sync."""
    m, params, batches = setup
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1)
    flq = FLConfig(num_clients=C, local_steps=H, client_lr=0.1,
                   compression=CompressionConfig(quantize_bits=8,
                                                 stochastic_rounding=False))
    copt, sopt = get_client_optimizer("sgd"), get_server_optimizer("fedavg")
    client_step = jax.jit(build_client_update_step(m.loss_fn, copt, fl))
    rngs = jax.random.split(jax.random.PRNGKey(2), C)
    deltas = [client_step(params, jax.tree.map(lambda x: x[c], batches),
                          rngs[c])[0] for c in range(C)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
    acfg = AsyncConfig(buffer_size=C)
    args = (stacked, jnp.ones(C), jnp.zeros(C), jnp.zeros(C), jnp.ones(C),
            jnp.arange(C, dtype=jnp.int32), jnp.float32(0.5),
            jax.random.PRNGKey(3))
    p_raw, _, _ = jax.jit(build_buffer_commit_step(sopt, fl, acfg))(
        params, (), *args)
    p_q, _, _ = jax.jit(build_buffer_commit_step(sopt, flq, acfg))(
        params, (), *args)
    diffs = [float(jnp.abs(a - b_).max()) for a, b_ in
             zip(jax.tree.leaves(p_raw), jax.tree.leaves(p_q))]
    assert max(diffs) > 0                     # quantization actually applied
    rel = [float(jnp.abs(a - b_).mean() / (jnp.abs(a - c).mean() + 1e-12))
           for a, b_, c in zip(jax.tree.leaves(p_raw), jax.tree.leaves(p_q),
                               jax.tree.leaves(params))]
    assert max(rel) < 0.1                     # but a faithful approximation


# ------------------------------------------------- adaptive staleness alpha
def test_constant_exponent_stays_the_default():
    """Satellite pin: the constant discount path is the default and its
    math is the documented 1/(1+s)^a (the NumPy-reference tests above pin
    the commit output for it)."""
    acfg = AsyncConfig()
    assert acfg.staleness_exponent == 0.5
    assert not acfg.adaptive_staleness
    assert acfg.initial_exponent() == pytest.approx(0.5)


def test_adaptive_exponent_accepted_and_validated():
    assert AsyncConfig(staleness_exponent="adaptive").adaptive_staleness
    with pytest.raises(ValueError, match="adaptive"):
        AsyncConfig(staleness_exponent="bogus")
    with pytest.raises(ValueError):
        AsyncConfig(staleness_exponent=-0.1)


def test_adaptive_controller_tracks_tail_staleness():
    """High observed tail staleness -> gentler exponent (slow sites keep
    contributing); near-fresh buffers -> sharp exponent (stale outliers
    are discounted hard).  Deterministic given the same observations."""
    fresh, stale = AdaptiveStalenessController(), AdaptiveStalenessController()
    for _ in range(20):
        a_fresh = fresh.update([0, 0, 1], delta_norm=1.0)
        a_stale = stale.update([10, 20, 40], delta_norm=1.0)
    assert a_fresh > a_stale
    # converged value matches the documented rule a = ln(1/w_floor)/ln(1+p90)
    p90 = float(np.quantile([10, 20, 40], 0.9))
    want = np.log(1 / stale.w_floor) / np.log1p(stale._stale_p90)
    assert a_stale == pytest.approx(want, rel=1e-6)
    assert stale._stale_p90 <= p90
    # determinism: same feed, same alphas
    again = AdaptiveStalenessController()
    for _ in range(20):
        a2 = again.update([10, 20, 40], delta_norm=1.0)
    assert a2 == a_stale


def test_adaptive_controller_norm_drift_brake():
    """A rising committed-step norm tightens the discount."""
    calm, drifty = AdaptiveStalenessController(), AdaptiveStalenessController()
    for i in range(10):
        a_calm = calm.update([4, 6, 8], delta_norm=1.0)
        a_drift = drifty.update([4, 6, 8], delta_norm=1.0 + 0.5 * i)
    assert a_drift > a_calm


def test_adaptive_controller_state_roundtrip():
    src = AdaptiveStalenessController()
    for _ in range(5):
        src.update([3, 7], delta_norm=2.0)
    dst = AdaptiveStalenessController()
    dst.set_state(src.state())
    assert dst.update([5, 9], 2.5) == src.update([5, 9], 2.5)


def test_commit_exponent_is_a_runtime_scalar():
    """The same compiled commit step serves different alphas (the adaptive
    controller moves it between commits without recompiling)."""
    K = 3
    params = {"x": jnp.zeros((4,), jnp.float32)}
    d = {"x": jnp.ones((K, 4), jnp.float32)}
    fl = FLConfig(mode="async")
    acfg = AsyncConfig(buffer_size=K, staleness_exponent="adaptive")
    s = 4.0
    for a in (0.0, 0.5, 2.0):
        p, _, _ = _commit(fl, acfg, params, d, jnp.ones(K), jnp.full(K, s),
                          jnp.ones(K), exponent=a)
        np.testing.assert_allclose(np.asarray(p["x"]),
                                   (1.0 + s) ** (-a), rtol=1e-5)
