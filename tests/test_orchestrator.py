"""Orchestrator subsystem units: selection, straggler mitigation, faults."""
import numpy as np

from repro.orchestrator import (AdaptiveSelection, FaultConfig, FaultInjector,
                                RandomSelection, StragglerPolicy,
                                apply_mitigation, make_hybrid_fleet,
                                simulate_round_times)


def test_fleet_shape_matches_paper_testbed():
    fleet = make_hybrid_fleet(30, 30)
    assert len(fleet) == 60
    assert sum(c.site == "hpc" for c in fleet) == 30
    assert any(c.profile.spot for c in fleet if c.site == "cloud")
    # HPC links are orders of magnitude faster than cloud
    hpc_bw = np.mean([c.profile.bandwidth_gbps for c in fleet if c.site == "hpc"])
    cloud_bw = np.mean([c.profile.bandwidth_gbps for c in fleet if c.site == "cloud"])
    assert hpc_bw > 5 * cloud_bw


def test_random_selection_unique():
    fleet = make_hybrid_fleet(5, 5)
    sel = RandomSelection(0).select(fleet, 6, 0)
    assert len(sel) == len(set(sel)) == 6


def test_adaptive_prefers_fast_reliable():
    fleet = make_hybrid_fleet(10, 10, seed=3)
    sel = AdaptiveSelection(seed=0, softmax_temp=0.3)
    counts = np.zeros(len(fleet))
    for rnd in range(200):
        for c in sel.select(fleet, 5, rnd):
            counts[c] += 1
    fast = [c.cid for c in fleet
            if c.profile.compute_tflops > 5 and c.profile.bandwidth_gbps > 5]
    slow = [c.cid for c in fleet if c.profile.compute_tflops < 1.5]
    assert counts[fast].mean() > counts[slow].mean()


def test_adaptive_load_balancing_excludes_slow_history():
    fleet = make_hybrid_fleet(10, 10, seed=1)
    # give one client terrible history
    for c in fleet:
        c.record(True, 1.0, 0)
    fleet[3].ema_round_time = 1000.0
    sel = AdaptiveSelection(seed=0, exclude_frac=0.2)
    picks = [sel.select(fleet, 8, r) for r in range(50)]
    freq3 = sum(3 in p for p in picks)
    assert freq3 == 0


def test_fastest_k_ties_admit_exactly_k():
    # regression: `times <= kth` admitted every client tied at the k-th
    # time, over-filling the round past k
    times = np.array([2.0, 1.0, 2.0, 2.0, 5.0])
    mask, dur = apply_mitigation(times, StragglerPolicy(fastest_k=2))
    assert mask.sum() == 2
    assert dur == 2.0
    # stable tie-break: the first client at the tied time wins the slot
    assert mask.tolist() == [1, 1, 0, 0, 0]
    mask, dur = apply_mitigation(np.array([3.0, 3.0, 3.0]),
                                 StragglerPolicy(fastest_k=1))
    assert mask.tolist() == [1, 0, 0] and dur == 3.0


def test_straggler_deadline_and_fastest_k():
    times = np.array([1.0, 2.0, 3.0, 10.0])
    mask, dur = apply_mitigation(times, StragglerPolicy(deadline_s=5.0))
    assert mask.tolist() == [1, 1, 1, 0]
    assert dur == 5.0
    mask, dur = apply_mitigation(times, StragglerPolicy(fastest_k=2))
    assert mask.tolist() == [1, 1, 0, 0]
    assert dur == 2.0
    mask, dur = apply_mitigation(times, StragglerPolicy())
    assert mask.sum() == 4 and dur == 10.0


def test_simulated_times_reflect_profiles():
    fleet = make_hybrid_fleet(2, 2, seed=0)
    rng = np.random.default_rng(0)
    pol = StragglerPolicy(contention_sigma=0.0)
    t = simulate_round_times(fleet, 1e13, 50_000_000, rng, pol)
    # gpu hpc nodes (idx 0) much faster than cpu cloud (idx 3)
    assert t[0] < t[3]


def test_fault_injector_dropout_rate():
    fleet = make_hybrid_fleet(20, 20, seed=0)
    inj = FaultInjector(FaultConfig(dropout_prob=0.2), seed=0)
    drops = []
    for _ in range(100):
        inj.step_round()
        m = inj.survive_mask(fleet)
        drops.append(1 - m.mean())
    assert 0.15 < np.mean(drops) < 0.35   # 0.2 dropout + reliability effects


def test_network_partition_hits_whole_site():
    fleet = make_hybrid_fleet(5, 5, seed=0)
    inj = FaultInjector(FaultConfig(partition_prob=1.0, partition_len=1), seed=2)
    inj.step_round()
    m = inj.survive_mask(fleet)
    sites = {c.site for c, alive in zip(fleet, m) if alive == 0}
    assert len(sites) == 1               # exactly one site partitioned
