"""Orchestrator subsystem units: selection, straggler mitigation, faults."""
import numpy as np

from repro.orchestrator import (AdaptiveSelection, FaultConfig, FaultInjector,
                                RandomSelection, StragglerPolicy,
                                apply_mitigation, make_hybrid_fleet,
                                simulate_round_times)


def test_fleet_shape_matches_paper_testbed():
    fleet = make_hybrid_fleet(30, 30)
    assert len(fleet) == 60
    assert sum(c.site == "hpc" for c in fleet) == 30
    assert any(c.profile.spot for c in fleet if c.site == "cloud")
    # HPC links are orders of magnitude faster than cloud
    hpc_bw = np.mean([c.profile.bandwidth_gbps for c in fleet if c.site == "hpc"])
    cloud_bw = np.mean([c.profile.bandwidth_gbps for c in fleet if c.site == "cloud"])
    assert hpc_bw > 5 * cloud_bw


def test_random_selection_unique():
    fleet = make_hybrid_fleet(5, 5)
    sel = RandomSelection(0).select(fleet, 6, 0)
    assert len(sel) == len(set(sel)) == 6


def test_adaptive_prefers_fast_reliable():
    fleet = make_hybrid_fleet(10, 10, seed=3)
    sel = AdaptiveSelection(seed=0, softmax_temp=0.3)
    counts = np.zeros(len(fleet))
    for rnd in range(200):
        for c in sel.select(fleet, 5, rnd):
            counts[c] += 1
    fast = [c.cid for c in fleet
            if c.profile.compute_tflops > 5 and c.profile.bandwidth_gbps > 5]
    slow = [c.cid for c in fleet if c.profile.compute_tflops < 1.5]
    assert counts[fast].mean() > counts[slow].mean()


def test_adaptive_load_balancing_excludes_slow_history():
    fleet = make_hybrid_fleet(10, 10, seed=1)
    # give one client terrible history
    for c in fleet:
        c.record(True, 1.0, 0)
    fleet[3].ema_round_time = 1000.0
    sel = AdaptiveSelection(seed=0, exclude_frac=0.2)
    picks = [sel.select(fleet, 8, r) for r in range(50)]
    freq3 = sum(3 in p for p in picks)
    assert freq3 == 0


def test_fastest_k_ties_admit_exactly_k():
    # regression: `times <= kth` admitted every client tied at the k-th
    # time, over-filling the round past k
    times = np.array([2.0, 1.0, 2.0, 2.0, 5.0])
    mask, dur = apply_mitigation(times, StragglerPolicy(fastest_k=2))
    assert mask.sum() == 2
    assert dur == 2.0
    # stable tie-break: the first client at the tied time wins the slot
    assert mask.tolist() == [1, 1, 0, 0, 0]
    mask, dur = apply_mitigation(np.array([3.0, 3.0, 3.0]),
                                 StragglerPolicy(fastest_k=1))
    assert mask.tolist() == [1, 0, 0] and dur == 3.0


def test_straggler_deadline_and_fastest_k():
    times = np.array([1.0, 2.0, 3.0, 10.0])
    mask, dur = apply_mitigation(times, StragglerPolicy(deadline_s=5.0))
    assert mask.tolist() == [1, 1, 1, 0]
    assert dur == 5.0
    mask, dur = apply_mitigation(times, StragglerPolicy(fastest_k=2))
    assert mask.tolist() == [1, 1, 0, 0]
    assert dur == 2.0
    mask, dur = apply_mitigation(times, StragglerPolicy())
    assert mask.sum() == 4 and dur == 10.0


def test_simulated_times_reflect_profiles():
    fleet = make_hybrid_fleet(2, 2, seed=0)
    rng = np.random.default_rng(0)
    pol = StragglerPolicy(contention_sigma=0.0)
    t = simulate_round_times(fleet, 1e13, 50_000_000, rng, pol)
    # gpu hpc nodes (idx 0) much faster than cpu cloud (idx 3)
    assert t[0] < t[3]


def _scalar_adaptive_select(sel, fleet, k, rnd):
    """The retired per-client scoring loop, kept verbatim as the oracle for
    the vectorised AdaptiveSelection.select (must stay bitwise identical)."""
    cands = list(fleet)
    timed = [c for c in cands if c.ema_round_time > 0]
    if len(timed) > 4 and sel.exclude_frac:
        cutoff = np.quantile([c.ema_round_time for c in timed],
                             1.0 - sel.exclude_frac)
        slow = {c.cid for c in timed if c.ema_round_time > cutoff}
        kept = [c for c in cands if c.cid not in slow]
        if len(kept) >= k:
            cands = kept
    scores = []
    for c in cands:
        s = (max(c.profile.compute_tflops, 1e-3) ** sel.a
             * max(c.profile.bandwidth_gbps, 1e-3) ** sel.b
             * max(c.success_rate, 0.05) ** sel.c)
        age = rnd - c.last_selected_round
        s *= 1.0 + sel.aging_boost * np.log1p(max(age, 0))
        scores.append(s)
    scores = np.asarray(scores, np.float64)
    p = np.exp(np.log(scores + 1e-12) / sel.temp)
    p /= p.sum()
    pick = sel.rng.choice([c.cid for c in cands], min(k, len(cands)),
                          replace=False, p=p)
    return list(pick)


def test_adaptive_vectorised_matches_scalar_trajectory():
    # the vectorised scoring pass must reproduce the scalar loop's
    # probability vector bit-for-bit, so with a shared rng state the whole
    # multi-round selection trajectory is identical
    fleet = make_hybrid_fleet(12, 12, seed=7)
    rng = np.random.default_rng(9)
    for c in fleet:                   # mixed history: some timed, some not
        if rng.random() < 0.6:
            c.record(bool(rng.random() < 0.8), float(rng.uniform(0.5, 30)),
                     int(rng.integers(0, 5)))
    vec = AdaptiveSelection(seed=11, exclude_frac=0.2, softmax_temp=0.7)
    ref = AdaptiveSelection(seed=11, exclude_frac=0.2, softmax_temp=0.7)
    for rnd in range(25):
        got = vec.select(fleet, 6, rnd)
        want = _scalar_adaptive_select(ref, fleet, 6, rnd)
        assert got == want, (rnd, got, want)
        for cid in got:               # evolve history like a real run
            fleet[cid].record(True, float(1.0 + cid % 5), rnd)
    # also pin the small-fleet branch (no quantile exclusion, k > len)
    tiny = make_hybrid_fleet(2, 1, seed=3)
    assert (AdaptiveSelection(seed=2).select(tiny, 8, 0)
            == _scalar_adaptive_select(AdaptiveSelection(seed=2), tiny, 8, 0))


def test_fault_injector_dropout_rate():
    fleet = make_hybrid_fleet(20, 20, seed=0)
    inj = FaultInjector(FaultConfig(dropout_prob=0.2), seed=0)
    drops = []
    for _ in range(100):
        inj.step_round()
        m = inj.survive_mask(fleet)
        drops.append(1 - m.mean())
    assert 0.15 < np.mean(drops) < 0.35   # 0.2 dropout + reliability effects


def test_network_partition_hits_whole_site():
    fleet = make_hybrid_fleet(5, 5, seed=0)
    inj = FaultInjector(FaultConfig(partition_prob=1.0, partition_len=1), seed=2)
    inj.step_round()
    m = inj.survive_mask(fleet)
    sites = {c.site for c, alive in zip(fleet, m) if alive == 0}
    assert len(sites) == 1               # exactly one site partitioned
