"""Incremental decoding must reproduce teacher-forced (prefill) logits —
the strongest correctness check on KV caches, ring buffers, SSM/xLSTM
recurrent states, MoE gather_tokens dispatch, and cross-attn caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model

pytestmark = pytest.mark.slow    # per-arch prefill+decode: minutes on CPU

# archs chosen to cover every cache type; starcoder2 exercises the sliding
# window ring buffer (reduced window = 8 < S).
ARCHS = ["granite-3-2b", "starcoder2-7b", "gemma-2b", "kimi-k2-1t-a32b",
         "jamba-1.5-large-398b", "xlstm-125m", "musicgen-medium",
         "llama-3.2-vision-90b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = reduced(get_config(arch))
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=8)
    m = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    B, S0, T = 2, 12, 4
    S = S0 + T
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    toks = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab,
                              jnp.int32)
    patches = (jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model),
                                 jnp.float32) if cfg.cross_attn_every else None)

    def pf(k):
        b = {"tokens": toks[:, :k]}
        if patches is not None:
            b["patches"] = patches
        return m.prefill(params, b, s_max=S)

    # incremental: prefill S0 then decode T steps
    lg, state = pf(S0)
    got = [lg]
    for t in range(T - 1):
        tok = toks[:, S0 + t]
        lg, state = m.decode_step(params, state, tok, jnp.int32(S0 + t), patches)
        got.append(lg)
    # reference: teacher-forced prefill at every length
    want = [pf(k)[0] for k in range(S0, S0 + T)]
    for t, (g, w) in enumerate(zip(got, want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{arch} step {t}")
