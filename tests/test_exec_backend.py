"""Execution-backend suite: ClosedFormBackend vs SchedulerBackend.

Pins the ISSUE-5 acceptance criteria:
  * with an uncontended pool and zero queue noise the scheduler backend's
    round durations match the closed form to <= 1e-6 (backend-level, sync
    orchestrator, and async orchestrator trajectories),
  * contended pools produce queue waits + elastic HPC->cloud overflow that
    land in RoundLog/CommitLog,
  * spot preemptions originate from the K8s adapter's event stream,
  * async kill/--resume under the scheduler backend replays bit-identically
    (pool state checkpointed),
  * recovery_policy="adaptive" chooses restart/resume/discard per fault and
    logs the decision in CommitLog.recovery_actions."""
import math
from dataclasses import asdict

import jax
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointManager
from repro.core import AsyncConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.exec import (ClientExecution, ClosedFormBackend, SchedulerBackend,
                        make_backend)
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (AsyncOrchestrator, FaultConfig, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet)
from repro.sched import HybridAdapter, K8sAdapter, SlurmAdapter

CFG = CNNConfig("tiny-cnn", (28, 28, 1), 9, channels=(4, 8), dense=32)
SEED, N_CLIENTS = 11, 6

_STEP_CACHE: dict = {}


def _share_steps(orch):
    key = (orch.async_cfg.buffer_size, orch.fl.local_steps,
           orch.async_cfg.staleness_exponent)
    if key in _STEP_CACHE:
        orch._client_update, orch._commit_step = _STEP_CACHE[key]
    else:
        _STEP_CACHE[key] = (orch._client_update, orch._commit_step)


def uncontended_pool(n: int = 64, preempt_per_min: float = 0.0,
                     seed: int = 0) -> HybridAdapter:
    """A pool that never queues: one node per possible in-flight job."""
    return HybridAdapter(
        slurm=SlurmAdapter(total_nodes=n, seed=seed),
        k8s=K8sAdapter(initial_nodes=n, max_nodes=n,
                       preempt_prob_per_min=preempt_per_min, seed=seed + 1))


def task(seed=SEED, n_clients=N_CLIENTS):
    data = medmnist_like(n=400, seed=seed)
    parts = partition_dirichlet(data.y, n_clients, alpha=0.5, seed=seed)
    fed = FederatedDataset(data, parts, seed=seed)
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(seed))
    fleet = make_hybrid_fleet(n_clients // 2, n_clients - n_clients // 2,
                              seed=seed, data_sizes=[len(p) for p in parts])
    return fed, model, params, fleet


# ---------------------------------------------------------------- unit level
def test_zero_contention_backend_equivalence():
    fleet = make_hybrid_fleet(4, 4, seed=0)
    pol = StragglerPolicy(contention_sigma=0.4)
    cf = ClosedFormBackend().bind(np.random.default_rng(7), pol)
    sb = SchedulerBackend(uncontended_pool()).bind(
        np.random.default_rng(7), pol)
    a = cf.execute_round(fleet, 2e12, 50_000_000, 0.0)
    b = sb.execute_round(fleet, 2e12, 50_000_000, 0.0)
    for x, y in zip(a, b):
        assert abs(x.duration_s - y.duration_s) <= 1e-6
        assert y.queue_wait_s == 0.0 and not y.overflowed
        assert y.site == x.site


def test_async_dispatch_equivalence_and_state_roundtrip():
    fleet = make_hybrid_fleet(2, 2, seed=1)
    pol = StragglerPolicy(contention_sigma=0.3)
    cf = ClosedFormBackend().bind(np.random.default_rng(3), pol)
    sb = SchedulerBackend(uncontended_pool()).bind(
        np.random.default_rng(3), pol)
    t = 0.0
    for c in fleet * 2:
        x = cf.execute(c, 2e12, 10_000_000, t)
        y = sb.execute(c, 2e12, 10_000_000, t)
        assert abs(x.duration_s - y.duration_s) <= 1e-6
        t += 0.5
    # pool state round-trips through a fresh backend
    twin = SchedulerBackend(uncontended_pool()).bind(
        np.random.default_rng(99), pol)
    twin.set_state(sb.state())
    assert twin.state() == sb.state()


def test_scheduler_backend_rejects_mismatched_pool_state():
    pol = StragglerPolicy()
    sb = SchedulerBackend(uncontended_pool(n=8)).bind(
        np.random.default_rng(0), pol)
    other = SchedulerBackend(uncontended_pool(n=16)).bind(
        np.random.default_rng(0), pol)
    with pytest.raises(ValueError, match="pool config"):
        other.set_state(sb.state())
    with pytest.raises(ValueError, match="closed-form"):
        sb.set_state({})


def test_contended_pool_queues_fifo():
    fleet = [c for c in make_hybrid_fleet(4, 0, seed=2)]
    pol = StragglerPolicy(contention_sigma=0.0)
    sb = SchedulerBackend(HybridAdapter(
        slurm=SlurmAdapter(total_nodes=1, seed=0),
        k8s=K8sAdapter(initial_nodes=4, max_nodes=4, seed=1),
        overflow_to_cloud=False)).bind(np.random.default_rng(5), pol)
    execs = sb.execute_round(fleet, 2e12, 10_000_000, 0.0)
    # one node, FIFO: client i waits for clients < i, exactly
    expect_wait = 0.0
    for e in execs:
        assert abs(e.queue_wait_s - expect_wait) <= 1e-6
        expect_wait += e.run_s
    assert execs[-1].queue_wait_s > 0


def test_elastic_overflow_lands_on_k8s():
    fleet = [c for c in make_hybrid_fleet(4, 0, seed=2)]
    pol = StragglerPolicy(contention_sigma=0.0)
    sb = SchedulerBackend(HybridAdapter(
        slurm=SlurmAdapter(total_nodes=2, seed=0),
        k8s=K8sAdapter(initial_nodes=4, max_nodes=4, seed=1))).bind(
            np.random.default_rng(5), pol)
    execs = sb.execute_round(fleet, 2e12, 10_000_000, 0.0)
    assert [e.site for e in execs] == ["hpc", "hpc", "cloud", "cloud"]
    assert sum(e.overflowed for e in execs) == 2
    assert all(e.queue_wait_s == 0.0 for e in execs)   # burst absorbed


# ----------------------------------------------------------- orchestrators
def sync_orch(backend, seed=SEED, straggler=None, faults=None):
    fed, model, params, fleet = task(seed)
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=FLConfig(num_clients=4, local_steps=1, client_lr=0.05),
        straggler=straggler or StragglerPolicy(contention_sigma=0.5),
        faults=faults or FaultConfig(),
        batch_size=8, flops_per_client_round=2e12, backend=backend,
        seed=seed)
    return orch, params


def test_sync_round_durations_match_across_backends():
    a, params = sync_orch(None)
    b, params2 = sync_orch(SchedulerBackend(uncontended_pool()))
    b._round_step = a._round_step          # share the jit cache
    a.run(params, 3)
    b.run(params2, 3)
    for la, lb in zip(a.logs, b.logs):
        assert abs(la.duration_s - lb.duration_s) <= 1e-6
        assert asdict(la) == asdict(lb)


def test_sync_contended_round_logs_queue_wait_and_overflow():
    pool = HybridAdapter(slurm=SlurmAdapter(total_nodes=1, seed=0),
                         k8s=K8sAdapter(initial_nodes=1, max_nodes=2,
                                        seed=1))
    orch, params = sync_orch(SchedulerBackend(pool))
    orch.run(params, 2)
    assert any(l.mean_queue_wait_s > 0 for l in orch.logs)
    assert any(l.n_overflow > 0 for l in orch.logs)


def async_orch(backend, seed=SEED, faults=None, mgr=None,
               checkpoint_every=0, buffer_size=3, max_staleness=20,
               recovery_policy=None):
    fed, model, params, fleet = task(seed)
    fa = faults or FaultConfig()
    if recovery_policy:
        fa = FaultConfig(**{**asdict(fa),
                            "recovery_policy": recovery_policy})
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=N_CLIENTS, local_steps=1,
                    client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=buffer_size, max_concurrency=4,
                              max_staleness=max_staleness),
        straggler=StragglerPolicy(contention_sigma=0.5),
        faults=fa, batch_size=8, flops_per_client_round=2e12,
        checkpoint_mgr=mgr, checkpoint_every=checkpoint_every,
        backend=backend, seed=seed)
    _share_steps(orch)
    return orch, params


def _trajectory(orch):
    def norm(d):
        # phase_wall is host-side profiling: never trajectory-comparable
        return {k: ("nan" if isinstance(v, float) and math.isnan(v) else v)
                for k, v in d.items() if k != "phase_wall"}
    return ([norm(asdict(l)) for l in orch.logs],
            list(orch.events_processed),
            [asdict(r) for r in orch.comm.records])


def test_async_trajectory_equivalence_uncontended():
    a, params = async_orch(None)
    b, params2 = async_orch(SchedulerBackend(uncontended_pool()))
    a.run(params, 4)
    b.run(params2, 4)
    assert _trajectory(a) == _trajectory(b)


def test_async_preemptions_originate_from_k8s_adapter():
    # NO injector spot_preempt_prob — every preempt must come from the pool
    pool = uncontended_pool(preempt_per_min=30.0)
    orch, params = async_orch(
        SchedulerBackend(pool),
        faults=FaultConfig(recovery_policy="discard"))
    orch.run(params, 6)
    preempts = [e for e in orch.events_processed if e[4] == "preempt"]
    assert preempts, "adapter preemptions never reached the event stream"
    assert orch.lost_to_faults > 0
    spot_cids = {c.cid for c in orch.fleet if c.profile.spot}
    assert {e[2] for e in preempts} <= spot_cids


@pytest.mark.parametrize("n_kill", [1, 2])
def test_scheduler_backend_kill_resume_bit_identical(tmp_path, n_kill):
    n_commits = 5
    faults = FaultConfig(recovery_policy="resume")
    mk = lambda **kw: async_orch(
        SchedulerBackend(uncontended_pool(n=3, preempt_per_min=20.0)),
        faults=faults, **kw)

    straight, params = mk()
    p_straight, _ = straight.run(params, n_commits)
    assert any(e[4] == "preempt" for e in straight.events_processed)

    mgr = AsyncCheckpointManager(tmp_path, keep=20)
    killed, params2 = mk(mgr=mgr, checkpoint_every=1)
    killed.run(params2, n_kill)
    assert killed.version == n_kill

    resumed, params3 = mk()
    resumed.checkpoint_mgr = None
    p0, st0 = mgr.restore_async(resumed, params3)
    assert resumed.version == n_kill
    p_resumed, _ = resumed.run(p0, n_commits, server_state=st0)

    assert _trajectory(resumed) == _trajectory(straight)
    for x, y in zip(jax.tree.leaves(p_resumed), jax.tree.leaves(p_straight)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=0, atol=1e-6)


def test_restore_rejects_backend_mismatch(tmp_path):
    mgr = AsyncCheckpointManager(tmp_path)
    orch, params = async_orch(SchedulerBackend(uncontended_pool()), mgr=mgr)
    orch.run(params, 2)
    other, params2 = async_orch(None)
    with pytest.raises(ValueError, match="config"):
        mgr.restore_async(other, params2)


def test_release_tolerates_pruned_terminal_job():
    """Regression: a job can go terminal on its own (pool preemption before
    an injector fault's strike time) and be pruned by a later dispatch;
    release() must not KeyError on it."""
    fleet = make_hybrid_fleet(0, 2, seed=3)
    pol = StragglerPolicy(contention_sigma=0.0)
    sb = SchedulerBackend(uncontended_pool()).bind(
        np.random.default_rng(1), pol)
    ex = sb.execute(fleet[0], 2e12, 10_000_000, 0.0)
    sb.hybrid.advance_to(ex.duration_s + 1.0)       # job completes
    sb.hybrid.prune_terminal()
    sb.release(ex.job_id, ex.duration_s + 2.0)      # must not raise
    sb.release("", 0.0)


def test_async_mixed_injector_and_pool_faults_run_to_completion():
    """Stress: adapter preemptions + injector dropouts/partitions + adaptive
    recovery on a CONTENDED pool all interleave without crashing, and both
    fault sources appear in the event stream."""
    pool = HybridAdapter(
        slurm=SlurmAdapter(total_nodes=2, seed=0),
        k8s=K8sAdapter(initial_nodes=2, max_nodes=3,
                       preempt_prob_per_min=20.0, seed=1))
    faults = FaultConfig(dropout_prob=0.15, partition_prob=0.3,
                         partition_len=2, recovery_policy="adaptive")
    orch, params = async_orch(SchedulerBackend(pool), faults=faults)
    orch.run(params, 8)
    assert orch.version == 8
    kinds = {e[4] for e in orch.events_processed if e[4]}
    assert "preempt" in kinds               # pool-origin
    assert kinds & {"dropout", "partition"}  # injector-origin


# ------------------------------------------------------- adaptive recovery
def test_adaptive_recovery_logs_actions():
    faults = FaultConfig(spot_preempt_prob=0.6, recovery_policy="adaptive")
    orch, params = async_orch(None, faults=faults)
    orch.run(params, 8)
    actions = [a for l in orch.logs for a in l.recovery_actions]
    assert actions, "no adaptive decisions were logged"
    assert all(a.split(":")[0] in ("preempt", "partition") for a in actions)
    assert all(a.split(":")[1] in ("restart", "resume", "discard")
               for a in actions)


def test_adaptive_recovery_discards_hopelessly_stale():
    # tight staleness cap + commit-per-arrival: once commits are flowing,
    # the projected staleness of a resumed attempt exceeds the cap and the
    # adaptive policy must start choosing discard over a doomed recovery
    faults = FaultConfig(spot_preempt_prob=0.6, recovery_policy="adaptive")
    orch, params = async_orch(None, faults=faults, max_staleness=1,
                              buffer_size=1)
    orch.run(params, 10)
    actions = [a for l in orch.logs for a in l.recovery_actions]
    assert actions
    assert any(a.endswith(":discard") for a in actions)


def test_adaptive_recovery_resumes_mostly_done_work():
    from dataclasses import replace

    from repro.orchestrator.async_server import PendingUpdate
    orch, params = async_orch(None)
    orch.clock, orch.version = 100.0, 2
    orch.fl = replace(orch.fl, local_steps=4)
    nearly_done = PendingUpdate(seq=0, cid=0, client_idx=0,
                                dispatch_version=2, dispatch_time=90.0,
                                duration_s=10.0, work_s=10.0, fault="preempt",
                                steps_done=3)
    assert orch._choose_recovery(nearly_done, 99.0) == "resume"
    fresh = PendingUpdate(seq=1, cid=1, client_idx=1, dispatch_version=2,
                          dispatch_time=98.0, duration_s=10.0, work_s=10.0,
                          fault="preempt", steps_done=0)
    assert orch._choose_recovery(fresh, 99.0) == "restart"
    orch.async_cfg = replace(orch.async_cfg, max_staleness=0)
    stale = PendingUpdate(seq=2, cid=2, client_idx=2, dispatch_version=2,
                          dispatch_time=0.0, duration_s=10.0, work_s=10.0,
                          fault="preempt", steps_done=0)
    assert orch._choose_recovery(stale, 99.0) == "discard"


# ----------------------------------------- spot-preempt-prob rate mapping
def test_equivalent_preempt_rate_math():
    from repro.orchestrator import equivalent_preempt_rate_per_min

    # P(strike within one mean-length attempt) must reproduce p_attempt:
    # strikes are exponential with rate lam/min, so
    # 1 - exp(-lam * t_mean/60) == p
    for p, mean_s in [(0.1, 30.0), (0.3, 90.0), (0.7, 5.0)]:
        lam = equivalent_preempt_rate_per_min(p, mean_s)
        assert abs(1.0 - np.exp(-lam * mean_s / 60.0) - p) < 1e-12
    assert equivalent_preempt_rate_per_min(0.0, 10.0) == 0.0
    assert equivalent_preempt_rate_per_min(-0.5, 10.0) == 0.0
    with pytest.raises(ValueError):
        equivalent_preempt_rate_per_min(1.0, 10.0)
    with pytest.raises(ValueError):
        equivalent_preempt_rate_per_min(0.5, 0.0)


def test_spot_preempt_prob_maps_onto_adapter_rate():
    """ROADMAP item: a closed-form run with FaultConfig.spot_preempt_prob
    (per-attempt Bernoulli) and a scheduler run whose K8s adapter reclaims
    at the equivalent exponential per-minute rate must both actually
    preempt, at broadly comparable frequency — and only spot clients.
    Counts are pinned per-seed as a regression anchor for the mapping."""
    from repro.core import payload_bytes
    from repro.orchestrator import equivalent_preempt_rate_per_min
    from repro.orchestrator.straggler import expected_attempt_s

    p_attempt = 0.3
    n_commits = 8

    cf_orch, params = async_orch(
        None, faults=FaultConfig(spot_preempt_prob=p_attempt,
                                 recovery_policy="discard"))
    cf_orch.run(params, n_commits)
    cf_pre = [e for e in cf_orch.events_processed if e[4] == "preempt"]

    mean_s = expected_attempt_s(
        cf_orch.fleet, 2e12,
        payload_bytes(params, cf_orch.fl.compression),
        StragglerPolicy(contention_sigma=0.5))
    rate = equivalent_preempt_rate_per_min(p_attempt, mean_s)

    sb_orch, params2 = async_orch(
        SchedulerBackend(uncontended_pool(preempt_per_min=rate)),
        faults=FaultConfig(recovery_policy="discard"))
    sb_orch.run(params2, n_commits)
    sb_pre = [e for e in sb_orch.events_processed if e[4] == "preempt"]

    assert cf_pre and sb_pre, "one of the regimes never preempted"
    spot_cids = {c.cid for c in sb_orch.fleet if c.profile.spot}
    assert {e[2] for e in sb_pre} <= spot_cids
    # per-attempt spot preempt frequency: same order of magnitude (the
    # adapter only strikes RUNNING preemptible pods, so some shortfall vs
    # the injector's unconditional per-attempt dice is expected)
    cf_frac = len(cf_pre) / len(cf_orch.events_processed)
    sb_frac = len(sb_pre) / len(sb_orch.events_processed)
    assert 0.2 <= sb_frac / cf_frac <= 5.0, (cf_frac, sb_frac)
    # regression anchor: exact per-seed counts under the fixed seed
    assert (len(cf_pre), len(sb_pre)) == (1, 1)
