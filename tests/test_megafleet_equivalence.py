"""Golden-trajectory equivalence: the batched mega-fleet engine AND the
vectorized event-window engine must be BIT-IDENTICAL to the per-event
``AsyncOrchestrator`` on flat fleets.

Both engines change only WHERE work happens (deferred vmap'd training,
batched top-up dispatch; the window engine additionally serves every RNG/
key draw from pre-drawn blocks, keeps arrivals in a structured-array store
and defers all loss fetches to one bundled host sync per commit) — every
host-side RNG draw stays in the legacy per-dispatch order, so params, the
processed-event trace, CommitLogs and the comm ledger must match exactly
(``np.array_equal``, not allclose): any drift is an RNG-ordering or
padding bug, not float noise.  Covered: plain, --secure-agg,
--exec-backend scheduler, every fault-recovery policy, timeout commits,
degenerate train chunks (padding), adaptive staleness, and kill/--resume
ACROSS engines in both directions."""
import tempfile
from dataclasses import asdict
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointManager
from repro.core import AsyncConfig, CompressionConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.exec import make_backend
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (AsyncOrchestrator, BatchedAsyncOrchestrator,
                                EventWindowOrchestrator, FaultConfig,
                                StragglerPolicy, make_hybrid_fleet)
from repro.sched import K8sAdapter, SlurmAdapter

CFG = CNNConfig("tiny-cnn", (28, 28, 1), 9, channels=(2, 4), dense=8)
MODEL = CNN(CFG)
DATA = medmnist_like(n=600, seed=0)
PARTS = partition_dirichlet(DATA.y, 8, alpha=0.5, seed=0)
PARAMS = MODEL.init(jax.random.PRNGKey(0))

# share compiled steps across the suite: the jit'd client update / commit
# step are pure functions of (model, FLConfig-relevant fields, K), identical
# for the legacy/batched pair under test — recompiling per orchestrator
# would dominate the suite's wall time
_STEP_CACHE = {}
_VSTEP_CACHE = {}      # the batched engine's lanes -> jit(vmap(step)) cache


def sched_backend():
    return make_backend(
        "scheduler",
        slurm=SlurmAdapter(total_nodes=3, seed=11),
        k8s=K8sAdapter(initial_nodes=1, max_nodes=3,
                       preempt_prob_per_min=2.0, seed=12))


def make_orch(engine, secure=False, scheduler=False, buffer_size=4,
              commit_timeout=0.0, staleness_exponent=0.5, faults=None,
              train_chunk=3, checkpoint_mgr=None, checkpoint_every=0,
              compression=None, commit_chunk=0, window=7):
    fleet = make_hybrid_fleet(4, 4, seed=3,
                              data_sizes=[len(p) for p in PARTS])
    fed = FederatedDataset(DATA, PARTS, seed=0)
    cls = {"legacy": AsyncOrchestrator,
           "batched": BatchedAsyncOrchestrator,
           "window": EventWindowOrchestrator}[engine]
    kw = {} if engine == "legacy" else {"train_chunk": train_chunk}
    if engine == "window":
        # a tiny window (default 7) forces frequent block refills and
        # partial-block re-syncs — the hardest regime for the blocked-RNG
        # bookkeeping
        kw["window"] = window
    orch = cls(
        fleet=fleet, fed_data=fed, loss_fn=MODEL.loss_fn,
        fl=FLConfig(mode="async", num_clients=8, local_steps=2,
                    client_lr=0.05, secure_agg=secure,
                    compression=compression or CompressionConfig()),
        async_cfg=AsyncConfig(buffer_size=buffer_size, max_concurrency=6,
                              max_staleness=50,
                              commit_timeout_s=commit_timeout,
                              commit_chunk=commit_chunk,
                              staleness_exponent=staleness_exponent),
        faults=faults or FaultConfig(),
        straggler=StragglerPolicy(contention_sigma=0.5),
        backend=sched_backend() if scheduler else None,
        batch_size=4, flops_per_client_round=2e12, seed=7,
        checkpoint_mgr=checkpoint_mgr, checkpoint_every=checkpoint_every,
        **kw)
    key = (secure, buffer_size, str(staleness_exponent), commit_chunk,
           str(compression))
    if key in _STEP_CACHE:
        orch._client_update, orch._commit_step = _STEP_CACHE[key]
    else:
        _STEP_CACHE[key] = (orch._client_update, orch._commit_step)
    if engine != "legacy":
        orch._vstep_cache = _VSTEP_CACHE
    return orch


def _logs(orch):
    """CommitLogs as dicts with NaN (un-evaluated eval_metric) normalised —
    NaN != NaN would fail an otherwise identical trajectory.  phase_wall is
    host profiling (nondeterministic by nature) and is excluded."""
    out = []
    for l in orch.logs:
        d = asdict(l)
        d.pop("phase_wall", None)
        out.append({k: (None if isinstance(v, float) and np.isnan(v) else v)
                    for k, v in d.items()})
    return out


def assert_same_trajectory(o1, p1, o2, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "params diverged (bit-level)"
    assert o1.events_processed == o2.events_processed
    assert _logs(o1) == _logs(o2)
    assert o1.comm.records == o2.comm.records
    assert o1.clock == o2.clock
    assert (o1.version, o1.updates_applied, o1.dropped_stale,
            o1.recovered_updates, o1.lost_to_faults) \
        == (o2.version, o2.updates_applied, o2.dropped_stale,
            o2.recovered_updates, o2.lost_to_faults)


ENGINES = ["batched", "window"]


def run_pair(n_commits=6, engine="batched", **kw):
    o1 = make_orch("legacy", **kw)
    p1, _ = o1.run(PARAMS, n_commits)
    o2 = make_orch(engine, **kw)
    p2, _ = o2.run(PARAMS, n_commits)
    assert_same_trajectory(o1, p1, o2, p2)
    return o1, o2


@pytest.mark.parametrize("engine", ENGINES)
def test_plain_run_bit_identical(engine):
    o1, _ = run_pair(engine=engine)
    assert o1.version == 6 and o1.updates_applied > 0


@pytest.mark.parametrize("engine", ENGINES)
def test_secure_agg_bit_identical(engine):
    run_pair(secure=True, engine=engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_scheduler_backend_bit_identical(engine):
    o1, _ = run_pair(engine=engine, scheduler=True,
                     faults=FaultConfig(dropout_prob=0.1,
                                        recovery_policy="adaptive"))
    assert any(e[3] for e in o1.events_processed), \
        "fault path never exercised"


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("policy", ["restart", "resume", "adaptive",
                                    "discard"])
def test_fault_recovery_bit_identical(policy, engine):
    o1, _ = run_pair(engine=engine,
                     faults=FaultConfig(dropout_prob=0.15,
                                        spot_preempt_prob=0.25,
                                        recovery_policy=policy))
    assert any(e[3] for e in o1.events_processed), \
        "fault path never exercised"


@pytest.mark.parametrize("engine", ENGINES)
def test_timeout_commits_bit_identical(engine):
    o1, _ = run_pair(buffer_size=16, commit_timeout=0.02, n_commits=4,
                     engine=engine)
    assert any(l.timeout_commit for l in o1.logs)


@pytest.mark.parametrize("engine", ENGINES)
def test_adaptive_staleness_bit_identical(engine):
    run_pair(staleness_exponent="adaptive", n_commits=5, engine=engine)


@pytest.mark.parametrize("chunk", [1, 2, 64])
def test_train_chunk_padding_bit_identical(chunk):
    # chunk=1: every job its own (padded-to-1) bucket; chunk=2: odd buckets
    # pad a lane; chunk=64 >> in-flight: one big padded bucket per snapshot
    run_pair(train_chunk=chunk, n_commits=4)


@pytest.mark.parametrize("window", [1, 256])
def test_window_size_extremes_bit_identical(window):
    # window=1 degenerates every block to a single draw; window=256 means
    # one refill serves the whole run (blocks die mostly un-consumed and
    # every sync replays a partial prefix)
    run_pair(n_commits=4, engine="window", window=window)


# ----------------------------------------------------- fused commit axis
_FUSED_COMP = CompressionConfig(quantize_bits=8, topk_frac=0.1,
                                stochastic_rounding=False)


def test_fused_commit_bit_identical():
    """The fused Pallas commit path (use_fused default on + deterministic
    quantize/top-k) keeps the engines bit-identical."""
    run_pair(compression=_FUSED_COMP)


def test_fused_secure_chunked_commit_bit_identical():
    """Integer-domain masked commits, accumulated in chunks, still replay
    identically across engines — the fused kernel is deterministic and the
    chunk algebra is additive."""
    run_pair(secure=True, commit_chunk=2,
             compression=CompressionConfig(quantize_bits=8,
                                           stochastic_rounding=False))


def test_kill_resume_fused_secure_chunked():
    """ISSUE 7 acceptance: chunked-commit + kill/resume bit-identity with
    use_fused on — the integer-domain mask stream and the fused kernels
    replay exactly from a checkpoint, across engines."""
    kw = dict(secure=True, commit_chunk=2,
              compression=CompressionConfig(quantize_bits=8,
                                            stochastic_rounding=False))
    o_full = make_orch("legacy", **kw)
    p_full, _ = o_full.run(PARAMS, 6)
    with tempfile.TemporaryDirectory() as td:
        o_half = make_orch("legacy", checkpoint_mgr=AsyncCheckpointManager(td),
                           checkpoint_every=3, **kw)
        o_half.run(PARAMS, 3)
        o_rest = make_orch("batched", **kw)
        o_rest.checkpoint_mgr = AsyncCheckpointManager(td)
        p_r, s_r = o_rest.checkpoint_mgr.restore_async(o_rest, PARAMS)
        assert o_rest.version == 3
        p2, _ = o_rest.run(p_r, 6, server_state=s_r)
    assert_same_trajectory(o_full, p_full, o_rest, p2)


@pytest.mark.parametrize("first,second", [("legacy", "batched"),
                                          ("batched", "legacy"),
                                          ("legacy", "window"),
                                          ("window", "legacy"),
                                          ("window", "batched")])
def test_kill_resume_across_engines(first, second):
    """A snapshot written by any engine restores into any other and
    replays the uninterrupted trajectory bit-identically — deferred
    deltas/losses are materialized at save, so the on-disk format is one."""
    o_full = make_orch(first)
    p_full, _ = o_full.run(PARAMS, 8)

    with tempfile.TemporaryDirectory() as td:
        o_half = make_orch(first, checkpoint_mgr=AsyncCheckpointManager(td),
                           checkpoint_every=4)
        o_half.run(PARAMS, 4)
        o_rest = make_orch(second)
        o_rest.checkpoint_mgr = AsyncCheckpointManager(td)
        p_r, s_r = o_rest.checkpoint_mgr.restore_async(o_rest, PARAMS)
        assert o_rest.version == 4
        p2, _ = o_rest.run(p_r, 8, server_state=s_r)
    assert_same_trajectory(o_full, p_full, o_rest, p2)


def test_cohort_window_matches_batched():
    """Cohort mode is NOT legacy-identical (shared-draw approximation),
    but the window engine must replay the batched engine's deterministic
    cohort trajectory bit-for-bit — blocked draws == sequential draws."""
    from repro.data import VirtualFederatedDataset
    from repro.orchestrator import make_mega_fleet

    def build(cls, **kw):
        orch = cls(
            fleet=make_mega_fleet(64, seed=3),
            fed_data=VirtualFederatedDataset(DATA, PARTS, seed=0,
                                             n_virtual=64),
            loss_fn=MODEL.loss_fn,
            fl=FLConfig(mode="async", num_clients=64, local_steps=2,
                        client_lr=0.05),
            async_cfg=AsyncConfig(buffer_size=4, max_concurrency=12,
                                  max_staleness=50),
            faults=FaultConfig(dropout_prob=0.1, recovery_policy="discard"),
            straggler=StragglerPolicy(contention_sigma=0.5),
            batch_size=4, flops_per_client_round=2e12, seed=7,
            train_chunk=3, **kw)
        orch._vstep_cache = _VSTEP_CACHE
        return orch

    o1 = build(BatchedAsyncOrchestrator)
    p1, _ = o1.run(PARAMS, 5)
    o2 = build(EventWindowOrchestrator, window=7)
    # share o1's jitted steps: identical closures, avoids a recompile
    o2._client_update, o2._commit_step = o1._client_update, o1._commit_step
    o2._update_fn = o1._update_fn
    p2, _ = o2.run(PARAMS, 5)
    assert_same_trajectory(o1, p1, o2, p2)
