"""Resume-equivalence for the crash-safe async regime: killing an
AsyncOrchestrator at any point and restoring from its checkpoint must
reproduce the uninterrupted run's trajectory — final params (<= 1e-6),
commit log, processed-event order and comm ledger.

Kill points exercised: right after the FIRST commit, mid-buffer (a
sim-time budget cut with updates sitting in the un-committed buffer), and
mid-partition (a whole-site network partition active at snapshot time,
with partial-progress recovery in flight)."""
import math
from dataclasses import asdict

import jax
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointManager
from repro.core import AsyncConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (AsyncOrchestrator, FaultConfig,
                                StragglerPolicy, make_hybrid_fleet)

CFG = CNNConfig("tiny-cnn", (28, 28, 1), 9, channels=(4, 8), dense=32)
SEED, N_CLIENTS = 11, 6

# the jit'd steps only depend on (model cfg, FLConfig, K, staleness exponent),
# all fixed per key here — share them across orchestrator instances so the
# suite compiles each step once instead of once per run
_STEP_CACHE: dict = {}


def _share_steps(orch):
    key = (orch.async_cfg.buffer_size, orch.fl.local_steps,
           orch.async_cfg.staleness_exponent)
    if key in _STEP_CACHE:
        orch._client_update, orch._commit_step = _STEP_CACHE[key]
    else:
        _STEP_CACHE[key] = (orch._client_update, orch._commit_step)


def make_orch(buffer_size=3, commit_timeout=0.0, faults=None, mgr=None,
              checkpoint_every=0, seed=SEED, local_steps=1, sigma=0.5):
    data = medmnist_like(n=400, seed=seed)
    parts = partition_dirichlet(data.y, N_CLIENTS, alpha=0.5, seed=seed)
    fed = FederatedDataset(data, parts, seed=seed)
    model = CNN(CFG)
    params = model.init(jax.random.PRNGKey(seed))
    fleet = make_hybrid_fleet(N_CLIENTS // 2, N_CLIENTS - N_CLIENTS // 2,
                              seed=seed, data_sizes=[len(p) for p in parts])
    orch = AsyncOrchestrator(
        fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
        fl=FLConfig(mode="async", num_clients=N_CLIENTS,
                    local_steps=local_steps, client_lr=0.05),
        async_cfg=AsyncConfig(buffer_size=buffer_size,
                              commit_timeout_s=commit_timeout,
                              max_concurrency=4),
        straggler=StragglerPolicy(contention_sigma=sigma),
        faults=faults or FaultConfig(),
        batch_size=8, flops_per_client_round=2e12,
        checkpoint_mgr=mgr, checkpoint_every=checkpoint_every, seed=seed)
    _share_steps(orch)
    return orch, params


def _norm(d):
    # phase_wall is host-side profiling: never trajectory-comparable
    return {k: ("nan" if isinstance(v, float) and math.isnan(v) else v)
            for k, v in d.items() if k != "phase_wall"}


def _trajectory(orch):
    return ([_norm(asdict(l)) for l in orch.logs],
            list(orch.events_processed),
            [asdict(r) for r in orch.comm.records])


def _assert_same_run(resumed, straight, p_resumed, p_straight):
    r_logs, r_ev, r_comm = _trajectory(resumed)
    s_logs, s_ev, s_comm = _trajectory(straight)
    assert r_logs == s_logs
    assert r_ev == s_ev
    assert r_comm == s_comm
    for a, b in zip(jax.tree.leaves(p_resumed), jax.tree.leaves(p_straight)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


PARTITION_FAULTS = dict(partition_prob=0.9, partition_len=3,
                        spot_preempt_prob=0.3, recovery_policy="resume")


@pytest.mark.parametrize("kill", ["first_commit", "mid_buffer",
                                  "mid_partition"])
def test_kill_and_resume_reproduces_uninterrupted_run(tmp_path, kill):
    n_commits = 6
    mk = lambda **kw: make_orch(faults=(FaultConfig(**PARTITION_FAULTS)
                                        if kill == "mid_partition" else None),
                                **kw)

    straight, params = mk()
    p_straight, _ = straight.run(params, n_commits)

    mgr = AsyncCheckpointManager(tmp_path, keep=20)
    killed, params2 = mk(mgr=mgr, checkpoint_every=1)
    if kill == "mid_buffer":
        # cut just before the 3rd commit's triggering arrival: the snapshot
        # must carry a non-empty pending-update buffer
        budget = float(np.nextafter(straight.logs[2].sim_time, 0.0))
        p_k, st_k = killed.run(params2, n_commits, max_sim_time=budget)
        assert killed._buffer, "kill point failed to land mid-buffer"
    else:
        k = 1 if kill == "first_commit" else 2
        p_k, st_k = killed.run(params2, k)
        assert killed.version == k
    if kill == "mid_partition":
        # the scenario must genuinely snapshot an ACTIVE partition
        assert killed.fault_injector._partition_left > 0
        assert any(e[4] == "partition" for e in straight.events_processed)

    resumed, params3 = mk(mgr=mgr)
    p0, st0 = mgr.restore_async(resumed, params3)
    assert resumed.version == killed.version
    p_resumed, _ = resumed.run(p0, n_commits, server_state=st0)

    _assert_same_run(resumed, straight, p_resumed, p_straight)


def test_resume_from_every_commit_boundary(tmp_path):
    """Kill/resume at ANY commit boundary reproduces the final params."""
    n_commits = 5
    mgr = AsyncCheckpointManager(tmp_path, keep=20)
    straight, params = make_orch(mgr=mgr, checkpoint_every=1)
    p_straight, _ = straight.run(params, n_commits)
    saved = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                   if d.is_dir())
    assert set(range(1, n_commits + 1)) <= set(saved)

    for k in range(1, n_commits):
        resumed, params2 = make_orch()
        resumed.checkpoint_mgr = None
        p0, st0 = mgr.restore_async(resumed, params2, rnd=k)
        assert resumed.version == k
        p_resumed, _ = resumed.run(p0, n_commits, server_state=st0)
        _assert_same_run(resumed, straight, p_resumed, p_straight)


def test_resume_with_timeout_commits(tmp_path):
    """Timeout-flush commits stamp on the T grid; a budget kill that lands
    between deadlines must still resume to the identical commit log."""
    n_commits = 5
    mk = lambda **kw: make_orch(buffer_size=64, commit_timeout=1.0, **kw)
    straight, params = mk()
    p_straight, _ = straight.run(params, n_commits)
    assert any(l.timeout_commit for l in straight.logs)

    mgr = AsyncCheckpointManager(tmp_path, keep=20)
    killed, params2 = mk(mgr=mgr)
    budget = (straight.logs[1].sim_time + straight.logs[2].sim_time) / 2
    killed.run(params2, n_commits, max_sim_time=budget)
    assert 0 < killed.version < n_commits

    resumed, params3 = mk(mgr=mgr)
    p0, st0 = mgr.restore_async(resumed, params3)
    p_resumed, _ = resumed.run(p0, n_commits, server_state=st0)
    _assert_same_run(resumed, straight, p_resumed, p_straight)


def test_restore_rejects_mismatched_config(tmp_path):
    mgr = AsyncCheckpointManager(tmp_path)
    orch, params = make_orch()
    orch.checkpoint_mgr = mgr
    orch.run(params, 2)
    other, params2 = make_orch(buffer_size=5)
    with pytest.raises(ValueError, match="config"):
        mgr.restore_async(other, params2)


def test_train_cli_checkpoint_and_resume(tmp_path, monkeypatch, capsys):
    """`--mode async --checkpoint-dir ... --resume` end to end: the old
    SystemExit path is gone and the resumed run continues the commit count."""
    from repro.launch import train

    argv = ["train", "--mode", "async", "--dataset", "medmnist",
            "--rounds", "2", "--clients-pool", "6", "--local-steps", "1",
            "--batch-size", "4", "--buffer-k", "2", "--max-concurrency", "3",
            "--checkpoint-every", "1",
            "--checkpoint-dir", str(tmp_path / "ck")]
    monkeypatch.setattr("sys.argv", argv)
    train.main()
    assert (tmp_path / "ck" / "LATEST").exists()

    monkeypatch.setattr("sys.argv", argv + ["--rounds", "4", "--resume"])
    train.main()
    out = capsys.readouterr().out
    assert "resumed async run at commit 2" in out
    assert '"commits": 4' in out
