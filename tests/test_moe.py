"""MoE dispatch correctness: the sort-based capacity dispatch must equal a
dense (every-token-through-selected-experts) reference when capacity is
ample, and degrade gracefully (drop, never corrupt) when it is not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.models.common import ParamBuilder


def build(E=8, K=2, D=32, F=64, cf=8.0, seed=0):
    cfg = MoEConfig(num_experts=E, top_k=K, d_expert=F, capacity_factor=cf)
    pb = ParamBuilder(jax.random.PRNGKey(seed), jnp.float32)
    moe_mod.init_moe(pb, ["moe"], D, cfg, 0)
    return cfg, pb.params["moe"]


def dense_reference(p, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eid = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    out = jnp.zeros_like(x2d)
    for k in range(cfg.top_k):
        for e in range(cfg.num_experts):
            sel = (eid[:, k] == e).astype(x2d.dtype)[:, None]
            h = jax.nn.silu(x2d @ p["w1"][e]) * (x2d @ p["w3"][e])
            y = h @ p["w2"][e]
            out = out + sel * gate[:, k:k + 1].astype(x2d.dtype) * y
    return out.reshape(B, S, D)


def test_capacity_dispatch_matches_dense_reference():
    cfg, p = build()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    got, aux = moe_mod.moe_apply(p, x, cfg=cfg, act="swiglu")
    want = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    assert np.isfinite(float(aux))


def test_tight_capacity_drops_but_never_corrupts():
    cfg, p = build(cf=0.25)     # deliberately starved
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32), jnp.float32)
    got, _ = moe_mod.moe_apply(p, x, cfg=cfg, act="swiglu")
    want = dense_reference(p, x, cfg)
    got, want = np.asarray(got), np.asarray(want)
    assert np.isfinite(got).all()
    # dropped tokens give smaller-magnitude outputs, never garbage
    assert (np.abs(got) <= np.abs(want) + np.abs(want).max() * 0.5 + 1e-3).mean() > 0.95


def test_load_balance_loss_orders_balanced_vs_skewed():
    cfg, p = build(E=4, K=1)
    # skew the router so everything goes to expert 0
    p_skew = dict(p, router=p["router"] * 0 + jnp.eye(32, 4) * 10)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32), jnp.float32)
    _, aux_norm = moe_mod.moe_apply(p, x, cfg=cfg, act="swiglu")
    _, aux_skew = moe_mod.moe_apply(p_skew, x, cfg=cfg, act="swiglu")
    assert float(aux_skew) > float(aux_norm)


def test_grad_flows_through_dispatch():
    cfg, p = build()
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 32), jnp.float32)

    def loss(p_):
        out, aux = moe_mod.moe_apply(p_, x, cfg=cfg, act="swiglu")
        return (out ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.isfinite(leaf).all()), path
    # experts that received tokens must have nonzero grads
    assert float(jnp.abs(g["w1"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
