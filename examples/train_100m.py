"""End-to-end driver (deliverable b): federated training of a ~100M-param
transformer LM for a few hundred rounds.

    PYTHONPATH=src python examples/train_100m.py                  # full (~100M)
    PYTHONPATH=src python examples/train_100m.py --ci             # CPU-budget

The model is the xlstm-125m assigned architecture's dense sibling at ~100M
params (12L, d=768, charLM head) — the paper's §6 "integration with
foundation models" scenario: federated next-token training over non-IID text
shards with FedProx + quantized updates.  --ci shrinks the model/steps so the
script verifies end-to-end on CPU in a few minutes; the full setting is the
deployable configuration.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, register
from repro.core import CompressionConfig, FLConfig
from repro.data import FederatedDataset, partition_by_group, shakespeare_like
from repro.models import build_model, param_count
from repro.orchestrator import Orchestrator, StragglerPolicy, make_hybrid_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ci", action="store_true",
                    help="CPU-budget: ~6M params, 40 rounds")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    if args.ci:
        cfg = ModelConfig(name="lm-ci", family="dense", n_layers=4,
                          d_model=256, n_heads=4, kv_heads=2, d_ff=1024,
                          vocab=512, dtype="float32")
        rounds = args.rounds or 40
        seq, n_seqs, batch = 64, 4000, 8
    else:
        # ~100M params: 12L x d768 x ff3072, 50k vocab
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, kv_heads=4, d_ff=3072,
                          vocab=50304, dtype="float32")
        rounds = args.rounds or 300
        seq, n_seqs, batch = 128, 20000, 16

    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n_params = param_count(params)
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    ds = shakespeare_like(n_seqs=n_seqs, seq_len=seq, vocab=min(cfg.vocab, 128),
                          n_speakers=40)
    parts = partition_by_group(ds.y, 20)
    fed = FederatedDataset(ds, parts)
    fleet = make_hybrid_fleet(10, 10, data_sizes=[len(p) for p in parts])

    fl = FLConfig(num_clients=8, local_steps=4, client_lr=0.25, fedprox_mu=0.01,
                  compression=CompressionConfig(quantize_bits=8))
    orch = Orchestrator(
        fleet=fleet, fed_data=fed, loss_fn=m.loss_fn, fl=fl,
        straggler=StragglerPolicy(fastest_k=6),
        batch_size=batch,
        flops_per_client_round=6 * n_params * batch * seq * 4,
        checkpoint_mgr=CheckpointManager(args.checkpoint_dir)
        if args.checkpoint_dir else None,
        checkpoint_every=25)

    t0 = time.time()
    params, _ = orch.run(params, rounds, verbose=False)
    losses = [l.client_loss for l in orch.logs]
    k = max(len(losses) // 10, 1)
    trace = [round(float(np.mean(losses[i:i + k])), 3)
             for i in range(0, len(losses), k)]
    print(f"loss trace (x{k}-round means): {trace}")
    print(f"{rounds} rounds in {time.time()-t0:.0f}s wall; "
          f"virtual cluster time {orch.virtual_clock:.0f}s; "
          f"payload {orch.comm.mean_bytes_per_client_round()/1e6:.1f} MB/client/round")
    assert losses[-1] < losses[0], "loss must decrease"
    print("OK")


if __name__ == "__main__":
    main()
