"""Federated fine-tuning of an assigned LLM architecture (paper §6:
"Integration with foundation models").

    PYTHONPATH=src python examples/federated_llm_finetune.py --arch gemma-2b

Runs the FL round step directly (no orchestrator) on a REDUCED variant of an
assigned arch, with sequential client execution — the same code path the
multi-pod dry-run lowers for the full configs, executed for real on CPU.
Shows: FedProx local training of a transformer, per-round compressed-delta
aggregation, and serve-after-train (prefill+decode with the trained params).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import CompressionConfig, FLConfig, build_fl_round_step
from repro.core.compression import payload_bytes
from repro.models import build_model
from repro.optim import get_client_optimizer, get_server_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    m = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)

    C, H, b, S = args.clients, args.local_steps, 4, 32
    fl = FLConfig(num_clients=C, local_steps=H, client_lr=0.1, fedprox_mu=0.01,
                  client_exec="sequential",
                  compression=CompressionConfig(quantize_bits=8,
                                                topk_frac=0.25))
    step = jax.jit(build_fl_round_step(
        m.loss_fn, get_client_optimizer("sgd"),
        get_server_optimizer("fedavg"), fl))
    print(f"arch={cfg.name}; uncompressed update "
          f"{payload_bytes(params, None)/1e6:.1f} MB -> compressed "
          f"{payload_bytes(params, fl.compression)/1e6:.1f} MB/client/round")

    # non-IID client corpora: each client's tokens drawn from its own range
    def client_batches(r):
        ks = jax.random.split(jax.random.PRNGKey(r), C)
        toks = []
        for c in range(C):
            lo = (c * cfg.vocab) // (2 * C)
            hi = lo + cfg.vocab // 2
            toks.append(jax.random.randint(ks[c], (H, b, S + 1), lo, hi,
                                           jnp.int32))
        t = jnp.stack(toks)
        leaves = {"tokens": t[..., :-1], "targets": t[..., 1:]}
        if cfg.cross_attn_every:
            leaves["patches"] = jax.random.normal(
                ks[0], (C, H, b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.n_codebooks:
            t4 = jax.random.randint(ks[0], (C, H, b, S + 1, cfg.n_codebooks),
                                    0, cfg.vocab, jnp.int32)
            leaves = {"tokens": t4[..., :-1, :], "targets": t4[..., 1:, :]}
        return leaves

    weights = jnp.ones((C,))
    state = ()
    for r in range(args.rounds):
        mask = jnp.asarray(np.random.default_rng(r).random(C) > 0.2,
                           jnp.float32)  # 20% dropouts
        params, state, metrics = step(params, state, client_batches(r),
                                      weights, mask, jax.random.PRNGKey(r))
        print(f"round {r}: loss {float(metrics['client_loss']):.4f} "
              f"delta {float(metrics['delta_norm']):.3f} "
              f"participation {float(metrics['participation']):.2f}")

    # serve with the fine-tuned weights
    prompt = jax.random.randint(rng, (2, 8, cfg.n_codebooks) if cfg.n_codebooks
                                else (2, 8), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": prompt}
    patches = None
    if cfg.cross_attn_every:
        patches = jax.random.normal(rng, (2, cfg.n_patches, cfg.d_model),
                                    jnp.float32)
        batch["patches"] = patches
    logits, st = m.prefill(params, batch, s_max=16)
    tok = logits.argmax(-1).astype(jnp.int32)
    logits, st = m.decode_step(params, st, tok, jnp.int32(8), patches)
    print("served logits:", logits.shape, "finite:",
          bool(jnp.isfinite(logits).all()))


if __name__ == "__main__":
    main()
