"""Asynchronous hybrid HPC+cloud scenario: FedBuff-style buffered commits
on a fleet where fast Infiniband GPU nodes coexist with slow, flaky cloud
spot VMs.

    PYTHONPATH=src python examples/async_hybrid_sim.py

What it shows:
  * the event-driven AsyncOrchestrator keeping every node busy — no round
    barrier, commits every K=4 arrivals with a 60 sim-second timeout so a
    quiet buffer still flushes,
  * staleness-discounted aggregation (slow nodes land many commits late;
    their updates are down-weighted 1/(1+s)^0.5, never discarded unless
    staler than 30 commits),
  * spot preemptions + dropouts folding into the same buffer semantics —
    preempted clients recover per FaultConfig.recovery_policy ("resume"
    here: they re-enqueue from their last completed local step instead of
    losing the attempt),
  * a head-to-head against the synchronous barrier loop on the SAME fleet
    and simulated-time budget.

Killing and resuming an async run
---------------------------------
The async regime is crash-safe end to end: with a checkpoint dir the
orchestrator snapshots its FULL state (global params, server opt state,
pending-update buffer, in-flight event heap, commit log, every RNG stream)
each --checkpoint-every commits and at exit, and --resume replays the exact
trajectory the uninterrupted run would have taken (bit-identical params and
commit log — pinned by tests/test_async_resume.py).  Try it:

    PYTHONPATH=src python -m repro.launch.train \
        --mode async --dataset medmnist --rounds 40 \
        --buffer-k 4 --commit-timeout 60 --max-concurrency 12 \
        --dropout-prob 0.1 --spot-preempt-prob 0.2 --recovery-policy resume \
        --checkpoint-dir ckpts/async_run --checkpoint-every 5
    # kill it at any point (Ctrl-C), then:
    PYTHONPATH=src python -m repro.launch.train \
        --mode async --dataset medmnist --rounds 40 \
        --buffer-k 4 --commit-timeout 60 --max-concurrency 12 \
        --dropout-prob 0.1 --spot-preempt-prob 0.2 --recovery-policy resume \
        --checkpoint-dir ckpts/async_run --checkpoint-every 5 --resume
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AsyncConfig, CompressionConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (AsyncOrchestrator, FaultConfig, Orchestrator,
                                StragglerPolicy, make_hybrid_fleet)

SEED, N = 0, 16
data = medmnist_like(n=3000, seed=SEED)
parts = partition_dirichlet(data.y, N, alpha=0.3, seed=SEED)
model = CNN(CNNConfig("med-cnn", (28, 28, 1), 9, channels=(8, 16), dense=64))
params = model.init(jax.random.PRNGKey(SEED))
eval_batch = jax.tree.map(jnp.asarray,
                          FederatedDataset(data, parts).eval_batch(512))
acc = jax.jit(model.accuracy)

fl = FLConfig(mode="async", num_clients=8, local_steps=2, client_lr=0.08,
              fedprox_mu=0.02,
              compression=CompressionConfig(quantize_bits=8))
straggler = StragglerPolicy(contention_sigma=0.6)
faults = FaultConfig(dropout_prob=0.1, spot_preempt_prob=0.2,
                     recovery_policy="resume")


def fresh_fleet():
    return make_hybrid_fleet(N // 2, N - N // 2, seed=SEED,
                             data_sizes=[len(p) for p in parts])


# ------------------------------------------------------------ async run
print("== async buffered training (K=4, T=60s, staleness^-0.5) ==")
anc = AsyncOrchestrator(
    fleet=fresh_fleet(), fed_data=FederatedDataset(data, parts, seed=SEED),
    loss_fn=model.loss_fn, fl=fl,
    async_cfg=AsyncConfig(buffer_size=4, staleness_exponent=0.5,
                          max_staleness=30, commit_timeout_s=60.0,
                          max_concurrency=12),
    straggler=straggler, faults=faults,
    batch_size=16, flops_per_client_round=2e12,
    eval_fn=lambda p: acc(p, eval_batch), eval_every=8, seed=SEED)
p_async, _ = anc.run(params, num_commits=40, verbose=True)

timeouts = sum(l.timeout_commit for l in anc.logs)
print(f"\n{anc.version} commits ({timeouts} by timeout), "
      f"{anc.updates_applied} updates applied, "
      f"{anc.dropped_stale} dropped as too stale, "
      f"mean staleness {np.mean([l.mean_staleness for l in anc.logs]):.2f}, "
      f"in {anc.clock:.0f} simulated seconds")
print(f"fault recovery (policy={faults.recovery_policy}): "
      f"{anc.recovered_updates} preempted attempts recovered "
      f"(+{anc.recovery_time_total / max(anc.recovered_updates, 1):.1f}s mean "
      f"delay), {anc.lost_to_faults} lost")

# ------------------------------------------- sync baseline, same sim budget
print("\n== sync barrier baseline on the same fleet & time budget ==")
sync = Orchestrator(
    fleet=fresh_fleet(), fed_data=FederatedDataset(data, parts, seed=SEED),
    loss_fn=model.loss_fn,
    fl=FLConfig(num_clients=8, local_steps=2, client_lr=0.08, fedprox_mu=0.02,
                compression=CompressionConfig(quantize_bits=8)),
    straggler=straggler, faults=faults,
    batch_size=16, flops_per_client_round=2e12,
    eval_fn=lambda p: acc(p, eval_batch), eval_every=4, seed=SEED)
rounds = 0
server_state = sync.init_server_state(params)
p_sync = params
while sync.virtual_clock < anc.clock:
    p_sync, server_state, log = sync.run_round(rounds, p_sync, server_state)
    rounds += 1
sync_updates = sum(l.participated for l in sync.logs)

print(f"{rounds} barrier rounds, {sync_updates} updates in "
      f"{sync.virtual_clock:.0f} simulated seconds")
print(f"\nupdate throughput: async {anc.updates_per_sim_second:.3f}/s vs "
      f"sync {sync_updates / sync.virtual_clock:.3f}/s "
      f"({anc.updates_per_sim_second / (sync_updates / sync.virtual_clock):.1f}x)")
print(f"accuracy at equal sim time: async {float(acc(p_async, eval_batch)):.3f} "
      f"vs sync {float(acc(p_sync, eval_batch)):.3f}")
