"""Hybrid HPC+cloud deployment simulation: the full systems story.

    PYTHONPATH=src python examples/hybrid_hpc_cloud_sim.py

Demonstrates every §3/§4 component working together:
  * scheduler adapters render + "execute" real sbatch scripts and K8s pod
    manifests against simulated SLURM/K8s backends (queueing, autoscaling,
    spot preemption),
  * adaptive selection reacts to client history,
  * deadline-based cutoff + 20% client dropouts,
  * a cloud-site network partition mid-training,
  * per-link byte/time accounting (Infiniband vs cloud uplink).

Execution backends
------------------
Client round times come from a pluggable ``ExecutionBackend``
(``repro.exec``).  The default ``closed-form`` backend prices compute +
transfer + lognormal contention analytically.  Pass
``--exec-backend scheduler`` to ``repro.launch.train`` (or hand the
orchestrator a ``SchedulerBackend``, as the last section below does) and
every client attempt is instead dispatched as a real ``JobSpec`` through
the ``HybridAdapter``: round durations then include SLURM queue waits,
elastic HPC->cloud overflow, K8s autoscaling, and spot preemptions that
originate from the K8s adapter's reclaim events.  Queue-wait and
placement accounting lands in ``RoundLog``/``CommitLog``, e.g.

    PYTHONPATH=src python -m repro.launch.train \\
        --mode async --exec-backend scheduler --hpc-nodes 8 \\
        --spot-preempt-per-min 2 --recovery-policy adaptive \\
        --checkpoint-dir ckpts/sched --resume

resumes bit-identically: the pool (queues, in-flight jobs, autoscale
level, adapter RNG) is checkpointed with the orchestrator.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig, FLConfig
from repro.data import FederatedDataset, medmnist_like, partition_dirichlet
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import (FaultConfig, Orchestrator, StragglerPolicy,
                                make_hybrid_fleet)
from repro.sched import HybridAdapter, JobSpec, JobState, K8sAdapter, SlurmAdapter

# ---------------------------------------------------------------- scheduling
print("== scheduler adapter: submitting one job per fleet node ==")
hy = HybridAdapter(slurm=SlurmAdapter(total_nodes=8),
                   k8s=K8sAdapter(initial_nodes=4, max_nodes=16,
                                  preempt_prob_per_min=2.0))
fleet = make_hybrid_fleet(8, 8, seed=1)
handles = []
for c in fleet:
    h = hy.submit(JobSpec(name=f"fl-client-{c.cid}",
                          command=f"python -m repro.worker --cid {c.cid}",
                          gpus_per_node=1 if c.profile.compute_tflops > 4 else 0,
                          site=c.site, preemptible=c.profile.spot))
    hy.set_workload(h.job_id, np.random.default_rng(c.cid).uniform(20, 90))
    handles.append(h)
print("sample sbatch script:\n" + handles[0].artifact[:260] + "...\n")
for _ in range(12):
    hy.advance(10.0)
states = [hy.poll(h.job_id).value for h in handles]
from collections import Counter
print("job states after 120 sim-seconds:", dict(Counter(states)))

# ---------------------------------------------------------------- training
print("\n== federated training with faults + deadline cutoff ==")
data = medmnist_like(n=3000)
parts = partition_dirichlet(data.y, 16, alpha=0.3)
fed = FederatedDataset(data, parts)
model = CNN(CNNConfig("med-cnn", (28, 28, 1), 9, channels=(8, 16), dense=64))
params = model.init(jax.random.PRNGKey(0))
fleet = make_hybrid_fleet(8, 8, data_sizes=[len(p) for p in parts])
eval_batch = jax.tree.map(jnp.asarray, fed.eval_batch(512))
acc = jax.jit(model.accuracy)

orch = Orchestrator(
    fleet=fleet, fed_data=fed, loss_fn=model.loss_fn,
    fl=FLConfig(num_clients=6, local_steps=2, client_lr=0.08, fedprox_mu=0.02,
                compression=CompressionConfig(quantize_bits=8)),
    straggler=StragglerPolicy(deadline_s=30.0, contention_sigma=0.4),
    faults=FaultConfig(dropout_prob=0.2, spot_preempt_prob=0.2,
                       partition_prob=0.1, partition_len=2),
    batch_size=16, flops_per_client_round=2e12,
    eval_fn=lambda p: acc(p, eval_batch), eval_every=4)
params, _ = orch.run(params, 12, verbose=True)

print("\nper-site communication:")
for site in ("hpc", "cloud"):
    cids = {c.cid for c in fleet if c.site == site}
    recs = [r for r in orch.comm.records if r.cid in cids and r.direction == "up"]
    if recs:
        print(f"  {site:6s}: {sum(r.nbytes for r in recs)/1e6:8.1f} MB up, "
              f"mean link time {np.mean([r.seconds for r in recs])*1e3:6.1f} ms")
print(f"\nfinal accuracy {orch.logs[-1].eval_metric:.3f} "
      f"after {orch.virtual_clock:.0f} simulated seconds")

# ------------------------------------------------- scheduler-backed timing
print("\n== same rounds, scheduler-backed execution (queue wait counts) ==")
from repro.exec import SchedulerBackend

sched_orch = Orchestrator(
    fleet=make_hybrid_fleet(8, 8, data_sizes=[len(p) for p in parts]),
    fed_data=fed, loss_fn=model.loss_fn,
    fl=FLConfig(num_clients=6, local_steps=2, client_lr=0.08,
                compression=CompressionConfig(quantize_bits=8)),
    straggler=StragglerPolicy(contention_sigma=0.4),
    batch_size=16, flops_per_client_round=2e12,
    backend=SchedulerBackend(HybridAdapter(
        slurm=SlurmAdapter(total_nodes=2),
        k8s=K8sAdapter(initial_nodes=2, max_nodes=3,
                       preempt_prob_per_min=2.0))))
sched_params = model.init(jax.random.PRNGKey(0))
sched_orch.run(sched_params, 4, verbose=True)
for lg in sched_orch.logs:
    print(f"  round {lg.rnd}: dur={lg.duration_s:6.1f}s "
          f"queue_wait={lg.mean_queue_wait_s:5.1f}s "
          f"overflowed={lg.n_overflow} preempted={lg.n_preempted}")
