"""Quickstart: federated learning on non-IID synthetic CIFAR-10 in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a 20-client hybrid HPC+cloud fleet, partitions data pathologically
(2 classes per client), and runs FedProx with 8-bit quantized updates +
fastest-k straggler mitigation — the paper's §5.1 configuration, scaled to
run in ~2 minutes on CPU.
"""
import jax
import jax.numpy as jnp

from repro.core import CompressionConfig, FLConfig
from repro.data import FederatedDataset, cifar10_like, partition_by_class
from repro.models.cnn import CNN, CNNConfig
from repro.orchestrator import Orchestrator, StragglerPolicy, make_hybrid_fleet

# 1. non-IID federated data (each client sees only 2 of 10 classes)
data = cifar10_like(n=4000)
parts = partition_by_class(data.y, n_clients=20, classes_per_client=2)
fed = FederatedDataset(data, parts)

# 2. model + fleet (10 HPC nodes + 10 cloud VMs, calibrated profiles)
model = CNN(CNNConfig("quickstart-cnn", (32, 32, 3), 10, channels=(8, 16),
                      dense=64))
params = model.init(jax.random.PRNGKey(0))
fleet = make_hybrid_fleet(10, 10, data_sizes=[len(p) for p in parts])

# 3. FedProx + compressed updates + fastest-k partial aggregation
fl = FLConfig(num_clients=8, local_steps=3, client_lr=0.08, fedprox_mu=0.02,
              compression=CompressionConfig(quantize_bits=8, topk_frac=0.25))
eval_batch = jax.tree.map(jnp.asarray, fed.eval_batch(512))
acc = jax.jit(model.accuracy)

orch = Orchestrator(
    fleet=fleet, fed_data=fed, loss_fn=model.loss_fn, fl=fl,
    straggler=StragglerPolicy(fastest_k=6),
    batch_size=16, flops_per_client_round=1e12,
    eval_fn=lambda p: acc(p, eval_batch), eval_every=3)

params, _ = orch.run(params, num_rounds=12, verbose=True)
print(f"\nfinal accuracy: {orch.logs[-1].eval_metric:.3f}")
print(f"simulated wall time: {orch.virtual_clock:.1f}s; "
      f"mean update payload: "
      f"{orch.comm.mean_bytes_per_client_round()/1e6:.2f} MB/client/round")
